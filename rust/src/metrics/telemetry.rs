//! Pipeline-wide telemetry: per-stage window snapshots that ride the data
//! path back to the coordinator, and the [`PipelineReport`] that merges
//! every stage into one run view.
//!
//! The problem this solves: in a multi-process run only the coordinator's
//! own measurements used to survive — each worker printed its
//! `WorkerReport` and exited, so "which boundary collapsed at t=12s" meant
//! reading N interleaved stdouts. Now every worker's sender thread
//! periodically serializes a [`StageSnapshot`] (window timeline since the
//! last flush, cumulative frame/compute/encode counters, queue depth,
//! resilience and per-stripe counters) and ships it **forward along the
//! data path** as a telemetry control record (see
//! [`crate::net::session`]). Each downstream worker relays what it
//! receives, so everything funnels into the coordinator's return link —
//! the one connection that is still alive when the last stage finishes.
//! (The backward HELLO/ACK path closes upstream-first at shutdown, so
//! final snapshots could never ride it.)
//!
//! Delivery is deliberately **best effort**: telemetry never enters the
//! replay buffer, never consumes data-plane sequence numbers, and never
//! delays an ACK — a lost conduit may drop a record. Every snapshot
//! therefore carries a per-stage sequence number (`snap`) and cumulative
//! counters, so the merge tolerates loss, duplication (striped senders
//! broadcast the final flush over every conduit) and out-of-order
//! arrival: counters come from the newest snapshot seen, window points
//! accumulate from every distinct one, and gaps are counted rather than
//! silently absorbed.
//!
//! The coordinator aggregates everything into a [`PipelineReport`] —
//! per-stage timelines, boundary alignment on microbatch seq, end-to-end
//! latency attribution — emitted as JSON (`--report-json`) and rendered
//! human-readably by `quantpipe report <run.json>`.

use super::{ResilienceSummary, StripeSummary, TimelinePoint};
use crate::util::json::Value;
use crate::Result;
use std::collections::{BTreeMap, BTreeSet};

/// Binary format version of a serialized [`StageSnapshot`].
pub const SNAPSHOT_VERSION: u8 = 1;

/// Flag bit: this is the stage's final snapshot (its sender drained).
const FLAG_LAST: u8 = 1;

/// One telemetry record: what a stage measured, flushed at window
/// boundaries and once more when its sender drains.
///
/// Counters (`frames`, `compute_ns`, …) are **cumulative since stage
/// start**, so a merge can always keep the newest snapshot's values and
/// lost records cost nothing but timeline points. `points` are
/// **incremental**: only the windows completed since the previous flush.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StageSnapshot {
    /// Stage index that produced this snapshot.
    pub stage: u32,
    /// Per-stage snapshot sequence number (0-based, dense). Gaps at the
    /// merge mean telemetry records were lost in transit.
    pub snap: u64,
    /// Final flush: the stage's sender has drained and will not report
    /// again. A stage whose merged view never saw this died mid-run.
    pub last: bool,
    /// Microbatches processed so far (cumulative).
    pub frames: u64,
    /// Lowest data-plane seq covered by this snapshot's window, or
    /// `u64::MAX` when no frame was seen since the previous flush.
    pub seq_lo: u64,
    /// One past the highest data-plane seq processed so far (high water).
    pub seq_hi: u64,
    /// Nanoseconds spent in stage compute so far (cumulative).
    pub compute_ns: u64,
    /// Nanoseconds spent in quantize+encode so far (cumulative).
    pub encode_ns: u64,
    /// Nanoseconds spent in decode+dequantize so far (cumulative).
    pub decode_ns: u64,
    /// Frames queued between compute and the transport writer at flush
    /// time — a persistent non-zero depth marks the pipeline bubble
    /// sitting *behind* this stage's output link.
    pub queue_depth: u32,
    /// Reconnect/replay counters for the stage's links (cumulative).
    pub resilience: ResilienceSummary,
    /// Per-stripe wire counters for the output link (cumulative; empty
    /// when the boundary is not striped).
    pub stripes: Vec<StripeSummary>,
    /// Monitor/controller windows completed since the previous flush.
    pub points: Vec<TimelinePoint>,
    /// Errors recorded so far (full list, newest snapshot wins).
    pub errors: Vec<String>,
}

impl StageSnapshot {
    /// Serialize to the compact little-endian wire payload (the telemetry
    /// control record's body; the wire layer prepends marker/kind/len).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(64 + self.points.len() * 33);
        out.push(SNAPSHOT_VERSION);
        out.push(if self.last { FLAG_LAST } else { 0 });
        out.extend_from_slice(&self.stage.to_le_bytes());
        out.extend_from_slice(&self.snap.to_le_bytes());
        out.extend_from_slice(&self.frames.to_le_bytes());
        out.extend_from_slice(&self.seq_lo.to_le_bytes());
        out.extend_from_slice(&self.seq_hi.to_le_bytes());
        out.extend_from_slice(&self.compute_ns.to_le_bytes());
        out.extend_from_slice(&self.encode_ns.to_le_bytes());
        out.extend_from_slice(&self.decode_ns.to_le_bytes());
        out.extend_from_slice(&self.queue_depth.to_le_bytes());
        let r = &self.resilience;
        out.extend_from_slice(&r.reconnects.to_le_bytes());
        out.extend_from_slice(&r.reaccepts.to_le_bytes());
        out.extend_from_slice(&r.replayed.to_le_bytes());
        out.extend_from_slice(&r.deduped.to_le_bytes());
        out.extend_from_slice(&r.stall_secs.to_le_bytes());
        // Element counts are u16 on the wire; the written elements are
        // clamped to the written count, so header and body can never
        // disagree (no real snapshot approaches these bounds — one
        // window point, a handful of stripes/errors).
        let cap = u16::MAX as usize;
        let stripes = &self.stripes[..self.stripes.len().min(cap)];
        out.extend_from_slice(&(stripes.len() as u16).to_le_bytes());
        for s in stripes {
            out.extend_from_slice(&s.frames.to_le_bytes());
            out.extend_from_slice(&s.bytes.to_le_bytes());
            out.extend_from_slice(&s.reconnects.to_le_bytes());
            out.extend_from_slice(&s.stall_secs.to_le_bytes());
        }
        let points = &self.points[..self.points.len().min(cap)];
        out.extend_from_slice(&(points.len() as u16).to_le_bytes());
        for p in points {
            out.extend_from_slice(&p.t.to_le_bytes());
            out.extend_from_slice(&p.bandwidth_bps.to_le_bytes());
            out.extend_from_slice(&p.rate.to_le_bytes());
            out.push(p.bits);
            out.extend_from_slice(&p.util.to_le_bytes());
        }
        let errors = &self.errors[..self.errors.len().min(cap)];
        out.extend_from_slice(&(errors.len() as u16).to_le_bytes());
        for e in errors {
            let b = e.as_bytes();
            let n = b.len().min(cap);
            out.extend_from_slice(&(n as u16).to_le_bytes());
            out.extend_from_slice(&b[..n]);
        }
        out
    }

    /// Parse a snapshot payload. Unknown versions and truncated records
    /// are errors (the caller counts and drops them — telemetry is best
    /// effort, so a bad record must never take the run down).
    pub fn from_bytes(buf: &[u8]) -> Result<StageSnapshot> {
        let mut r = Reader { buf, pos: 0 };
        let version = r.u8()?;
        anyhow::ensure!(
            version == SNAPSHOT_VERSION,
            "unsupported telemetry snapshot version {version}"
        );
        let flags = r.u8()?;
        let stage = r.u32()?;
        let snap = r.u64()?;
        let frames = r.u64()?;
        let seq_lo = r.u64()?;
        let seq_hi = r.u64()?;
        let compute_ns = r.u64()?;
        let encode_ns = r.u64()?;
        let decode_ns = r.u64()?;
        let queue_depth = r.u32()?;
        let resilience = ResilienceSummary {
            reconnects: r.u64()?,
            reaccepts: r.u64()?,
            replayed: r.u64()?,
            deduped: r.u64()?,
            stall_secs: r.f64()?,
        };
        let n_stripes = r.u16()? as usize;
        let mut stripes = Vec::with_capacity(n_stripes);
        for _ in 0..n_stripes {
            stripes.push(StripeSummary {
                frames: r.u64()?,
                bytes: r.u64()?,
                reconnects: r.u64()?,
                stall_secs: r.f64()?,
            });
        }
        let n_points = r.u16()? as usize;
        let mut points = Vec::with_capacity(n_points);
        for _ in 0..n_points {
            points.push(TimelinePoint {
                t: r.f64()?,
                stage: stage as usize,
                bandwidth_bps: r.f64()?,
                rate: r.f64()?,
                bits: r.u8()?,
                util: r.f64()?,
            });
        }
        let n_errors = r.u16()? as usize;
        let mut errors = Vec::with_capacity(n_errors);
        for _ in 0..n_errors {
            let n = r.u16()? as usize;
            errors.push(String::from_utf8_lossy(r.take(n)?).into_owned());
        }
        Ok(StageSnapshot {
            stage,
            snap,
            last: flags & FLAG_LAST != 0,
            frames,
            seq_lo,
            seq_hi,
            compute_ns,
            encode_ns,
            decode_ns,
            queue_depth,
            resilience,
            stripes,
            points,
            errors,
        })
    }

    /// Cheap identity probe — `(stage, snap)` — without a full parse.
    /// Relay hops use it to dedup broadcast copies before re-forwarding.
    pub fn peek_id(buf: &[u8]) -> Option<(u32, u64)> {
        if buf.len() < 14 || buf[0] != SNAPSHOT_VERSION {
            return None;
        }
        let stage = u32::from_le_bytes(buf[2..6].try_into().ok()?);
        let snap = u64::from_le_bytes(buf[6..14].try_into().ok()?);
        Some((stage, snap))
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        anyhow::ensure!(
            self.pos + n <= self.buf.len(),
            "telemetry snapshot truncated at byte {}",
            self.pos
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

// ---------------------------------------------------------------------------
// Relay queue (per-worker hop)
// ---------------------------------------------------------------------------

/// Telemetry payloads a worker received from upstream and owes downstream.
/// Deduplicates by `(stage, snap)` at the hop, so striped broadcast copies
/// don't multiply across the chain; unparseable payloads are forwarded
/// verbatim (a middle hop must not censor what the coordinator could still
/// count as dropped).
#[derive(Debug, Default)]
pub struct TelemetryRelay {
    queue: Vec<Vec<u8>>,
    seen: BTreeSet<(u32, u64)>,
}

impl TelemetryRelay {
    /// Offer one inbound payload; duplicates of an already-relayed
    /// snapshot are dropped. Returns whether it was queued.
    pub fn offer(&mut self, payload: Vec<u8>) -> bool {
        if let Some(id) = StageSnapshot::peek_id(&payload) {
            if !self.seen.insert(id) {
                return false;
            }
        }
        self.queue.push(payload);
        true
    }

    /// Take everything queued (FIFO).
    pub fn drain(&mut self) -> Vec<Vec<u8>> {
        std::mem::take(&mut self.queue)
    }

    /// Anything waiting to be forwarded?
    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }
}

// ---------------------------------------------------------------------------
// Merged per-stage view
// ---------------------------------------------------------------------------

/// One stage's merged timeline inside a [`PipelineReport`].
#[derive(Debug, Default)]
pub struct StageReport {
    /// Stage index.
    pub stage: u32,
    /// Microbatches processed (newest snapshot's cumulative count).
    pub frames: u64,
    /// Lowest data-plane seq any snapshot covered (`u64::MAX` until one
    /// did). Nonzero on a stage that joined or resumed mid-run.
    pub seq_lo: u64,
    /// One past the highest data-plane seq processed.
    pub seq_hi: u64,
    /// Cumulative stage compute nanoseconds.
    pub compute_ns: u64,
    /// Cumulative encode nanoseconds.
    pub encode_ns: u64,
    /// Cumulative decode nanoseconds.
    pub decode_ns: u64,
    /// Queue depth at the last flush.
    pub queue_depth: u32,
    /// The stage's final snapshot arrived (false = it died mid-run, or
    /// its last record was lost).
    pub complete: bool,
    /// Distinct snapshots merged.
    pub snaps: u64,
    /// Snapshot-sequence gaps observed (telemetry records lost in
    /// transit; the counters self-heal, only timeline points are gone).
    pub missed: u64,
    /// Merged window timeline, ascending by `t`.
    pub points: Vec<TimelinePoint>,
    /// Reconnect/replay counters for the stage's links.
    pub resilience: ResilienceSummary,
    /// Per-stripe wire counters for the output link.
    pub stripes: Vec<StripeSummary>,
    /// Errors the stage reported.
    pub errors: Vec<String>,
    seen: BTreeSet<u64>,
    newest: Option<u64>,
}

impl StageReport {
    /// Distinct bitwidth sequence (collapsed) — the stage's Fig 5 track,
    /// computed by the same [`super::Timeline::bits_sequence`] the
    /// in-process report uses (every merged point carries this stage's
    /// index, so the filter is a no-op here).
    pub fn bits_sequence(&self) -> Vec<u8> {
        let tl = super::Timeline { points: self.points.clone() };
        tl.bits_sequence(self.stage as usize)
    }

    /// Mean compute seconds per microbatch.
    pub fn mean_compute_s(&self) -> f64 {
        if self.frames == 0 {
            0.0
        } else {
            self.compute_ns as f64 / 1e9 / self.frames as f64
        }
    }

    fn apply(&mut self, s: StageSnapshot) {
        if !self.seen.insert(s.snap) {
            return; // duplicate (striped broadcast, replayed relay)
        }
        self.snaps = self.seen.len() as u64;
        let expected = self.seen.iter().next_back().map_or(0, |m| m + 1);
        self.missed = expected - self.snaps;
        // A run-wide minimum is order-independent: fold every snapshot's
        // window in, not just the newest.
        self.seq_lo = self.seq_lo.min(s.seq_lo);
        // Counters are cumulative: the newest snapshot wins, regardless of
        // arrival order.
        if self.newest.map_or(true, |n| s.snap > n) {
            self.newest = Some(s.snap);
            self.frames = s.frames;
            self.seq_hi = s.seq_hi;
            self.compute_ns = s.compute_ns;
            self.encode_ns = s.encode_ns;
            self.decode_ns = s.decode_ns;
            self.queue_depth = s.queue_depth;
            self.resilience = s.resilience;
            self.stripes = s.stripes;
            self.errors = s.errors;
        }
        self.complete |= s.last;
        // Points are incremental: accumulate from every distinct snapshot
        // and keep the timeline ordered even under out-of-order arrival.
        // Snapshots arrive in order in the common case, so only sort when
        // the appended batch actually broke monotonicity — the re-sort is
        // the exception, not an O(n log n) cost per ingested record.
        let boundary = self.points.len().saturating_sub(1);
        self.points.extend(s.points);
        let broke_order = self.points[boundary..].windows(2).any(|w| w[0].t > w[1].t);
        if broke_order {
            self.points
                .sort_by(|a, b| a.t.partial_cmp(&b.t).unwrap_or(std::cmp::Ordering::Equal));
        }
    }
}

// ---------------------------------------------------------------------------
// The merged run view
// ---------------------------------------------------------------------------

/// One client stream's end-to-end view under the serving plane
/// ([`crate::pipeline::serve`]): admission counters from the scheduler
/// plus completion-latency percentiles measured at the coordinator's
/// sink. Empty `streams` list = the classic single-stream coordinator.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StreamSummary {
    /// Stream ID (the frame-header tag).
    pub stream: u32,
    /// WRR weight the stream was admitted with (post-clamp).
    pub weight: u32,
    /// Microbatches completed end to end on this stream.
    pub frames: u64,
    /// Backpressure stalls this stream's client absorbed at admission —
    /// the "who was held back" counter the fairness tests assert on.
    pub stalls: u64,
    /// Median completion latency (submit → logits), seconds.
    pub p50_latency_s: f64,
    /// 99th-percentile completion latency, seconds.
    pub p99_latency_s: f64,
}

/// The coordinator's end-to-end measurements, embedded in the
/// [`PipelineReport`] beside the per-stage telemetry.
#[derive(Debug, Clone, Default)]
pub struct CoordinatorSummary {
    /// Images scored.
    pub images: u64,
    /// Microbatches completed end to end.
    pub microbatches: u64,
    /// Wall-clock run seconds.
    pub wall_secs: f64,
    /// End-to-end images/sec.
    pub throughput: f64,
    /// Top-1 accuracy.
    pub accuracy: f64,
    /// Median end-to-end microbatch latency, seconds.
    pub p50_latency_s: f64,
    /// 99th-percentile end-to-end microbatch latency, seconds.
    pub p99_latency_s: f64,
    /// Per-stream serving-plane rows (empty on single-stream runs).
    pub streams: Vec<StreamSummary>,
    /// Coordinator-side failures (empty on a clean run).
    pub errors: Vec<String>,
}

/// Every stage's timeline plus the coordinator's end-to-end view, merged
/// into the single artifact a multi-process run produces.
///
/// Fed by [`PipelineReport::ingest`] (raw telemetry payloads off the
/// return link) and [`PipelineReport::merge`] (parsed snapshots);
/// serialized with [`PipelineReport::to_json`] / parsed back with
/// [`PipelineReport::from_json`]; rendered by [`PipelineReport::render`]
/// (the `quantpipe report` subcommand).
#[derive(Debug, Default)]
pub struct PipelineReport {
    /// Per-stage merged views, keyed (and therefore ordered) by stage.
    pub stages: BTreeMap<u32, StageReport>,
    /// The coordinator's own measurements, when this report came from a
    /// live run (absent in a worker-only aggregation).
    pub coordinator: Option<CoordinatorSummary>,
    /// Telemetry payloads that failed to parse and were dropped.
    pub dropped: u64,
}

impl PipelineReport {
    /// Empty report.
    pub fn new() -> Self {
        Self::default()
    }

    /// Merge one parsed snapshot (idempotent per `(stage, snap)`).
    pub fn merge(&mut self, snap: StageSnapshot) {
        let stage = snap.stage;
        let entry = self.stages.entry(stage).or_insert_with(|| StageReport {
            stage,
            // The "no seq seen yet" sentinel, so the min-fold works.
            seq_lo: u64::MAX,
            ..StageReport::default()
        });
        entry.apply(snap);
    }

    /// Parse + merge one raw telemetry payload; garbage is counted in
    /// [`PipelineReport::dropped`], never an error.
    pub fn ingest(&mut self, payload: &[u8]) {
        match StageSnapshot::from_bytes(payload) {
            Ok(s) => self.merge(s),
            Err(_) => self.dropped += 1,
        }
    }

    /// Number of stages that reported at least one snapshot.
    pub fn stage_count(&self) -> usize {
        self.stages.len()
    }

    /// Boundary alignment on microbatch seq: for each adjacent pair of
    /// reporting stages, how many frames the downstream stage is short.
    /// On a complete clean run every entry is zero; a died stage shows up
    /// as the pipeline bubble it left behind.
    pub fn boundary_shortfalls(&self) -> Vec<(u32, u32, u64)> {
        let mut out = Vec::new();
        let stages: Vec<&StageReport> = self.stages.values().collect();
        for w in stages.windows(2) {
            let (a, b) = (w[0], w[1]);
            out.push((a.stage, b.stage, a.frames.saturating_sub(b.frames)));
        }
        out
    }

    /// Machine-readable report (non-finite numbers map to `null` — the
    /// document must always re-parse).
    pub fn to_json(&self) -> Value {
        let num = Value::num_or_null;
        let mut m = BTreeMap::new();
        m.insert("schema".into(), Value::Str("quantpipe.pipeline_report.v1".into()));
        m.insert("dropped".into(), Value::Num(self.dropped as f64));
        let stages = self
            .stages
            .values()
            .map(|s| {
                let mut sm = BTreeMap::new();
                sm.insert("stage".into(), Value::Num(s.stage as f64));
                sm.insert("frames".into(), Value::Num(s.frames as f64));
                sm.insert(
                    "seq_lo".into(),
                    if s.seq_lo == u64::MAX { Value::Null } else { Value::Num(s.seq_lo as f64) },
                );
                sm.insert("seq_hi".into(), Value::Num(s.seq_hi as f64));
                sm.insert("compute_ns".into(), Value::Num(s.compute_ns as f64));
                sm.insert("encode_ns".into(), Value::Num(s.encode_ns as f64));
                sm.insert("decode_ns".into(), Value::Num(s.decode_ns as f64));
                sm.insert("queue_depth".into(), Value::Num(s.queue_depth as f64));
                sm.insert("complete".into(), Value::Bool(s.complete));
                sm.insert("snaps".into(), Value::Num(s.snaps as f64));
                sm.insert("missed".into(), Value::Num(s.missed as f64));
                let tl = super::Timeline { points: s.points.clone() };
                sm.insert("timeline".into(), tl.to_json());
                sm.insert("resilience".into(), s.resilience.to_json());
                sm.insert("stripes".into(), StripeSummary::list_to_json(&s.stripes));
                sm.insert(
                    "errors".into(),
                    Value::Arr(s.errors.iter().map(|e| Value::Str(e.clone())).collect()),
                );
                Value::Obj(sm)
            })
            .collect();
        m.insert("stages".into(), Value::Arr(stages));
        match &self.coordinator {
            Some(c) => {
                let mut cm = BTreeMap::new();
                cm.insert("images".into(), Value::Num(c.images as f64));
                cm.insert("microbatches".into(), Value::Num(c.microbatches as f64));
                cm.insert("wall_secs".into(), num(c.wall_secs));
                cm.insert("throughput".into(), num(c.throughput));
                cm.insert("accuracy".into(), num(c.accuracy));
                cm.insert("p50_latency_s".into(), num(c.p50_latency_s));
                cm.insert("p99_latency_s".into(), num(c.p99_latency_s));
                cm.insert(
                    "streams".into(),
                    Value::Arr(
                        c.streams
                            .iter()
                            .map(|st| {
                                let mut tm = BTreeMap::new();
                                tm.insert("stream".into(), Value::Num(st.stream as f64));
                                tm.insert("weight".into(), Value::Num(st.weight as f64));
                                tm.insert("frames".into(), Value::Num(st.frames as f64));
                                tm.insert("stalls".into(), Value::Num(st.stalls as f64));
                                tm.insert("p50_latency_s".into(), num(st.p50_latency_s));
                                tm.insert("p99_latency_s".into(), num(st.p99_latency_s));
                                Value::Obj(tm)
                            })
                            .collect(),
                    ),
                );
                cm.insert(
                    "errors".into(),
                    Value::Arr(c.errors.iter().map(|e| Value::Str(e.clone())).collect()),
                );
                m.insert("coordinator".into(), Value::Obj(cm));
            }
            None => {
                m.insert("coordinator".into(), Value::Null);
            }
        }
        Value::Obj(m)
    }

    /// Parse a report back from its JSON form (the `quantpipe report`
    /// subcommand reads the file `--report-json` wrote).
    pub fn from_json(v: &Value) -> Result<PipelineReport> {
        let schema = v.at("schema")?.as_str()?;
        anyhow::ensure!(
            schema == "quantpipe.pipeline_report.v1",
            "not a pipeline report (schema {schema:?})"
        );
        let mut report = PipelineReport {
            dropped: v.at("dropped")?.as_u64()?,
            ..PipelineReport::default()
        };
        for sv in v.at("stages")?.as_arr()? {
            let stage = sv.at("stage")?.as_u64()? as u32;
            let mut points = Vec::new();
            for pv in sv.at("timeline")?.as_arr()? {
                points.push(TimelinePoint {
                    t: pv.at("t")?.as_f64()?,
                    stage: stage as usize,
                    // An absent bandwidth means the unconstrained-link
                    // "infinite" measurement (see Timeline::to_json).
                    bandwidth_bps: match pv.get("bandwidth_bps") {
                        Some(b) => b.as_f64()?,
                        None => f64::INFINITY,
                    },
                    rate: pv.at("rate")?.as_f64()?,
                    bits: pv.at("bits")?.as_u64()? as u8,
                    util: pv.at("util")?.as_f64()?,
                });
            }
            let rv = sv.at("resilience")?;
            let resilience = ResilienceSummary {
                reconnects: rv.at("reconnects")?.as_u64()?,
                reaccepts: rv.at("reaccepts")?.as_u64()?,
                replayed: rv.at("replayed")?.as_u64()?,
                deduped: rv.at("deduped")?.as_u64()?,
                stall_secs: match rv.at("stall_secs")? {
                    Value::Null => 0.0,
                    other => other.as_f64()?,
                },
            };
            let mut stripes = Vec::new();
            for tv in sv.at("stripes")?.as_arr()? {
                stripes.push(StripeSummary {
                    frames: tv.at("frames")?.as_u64()?,
                    bytes: tv.at("bytes")?.as_u64()?,
                    reconnects: tv.at("reconnects")?.as_u64()?,
                    stall_secs: match tv.at("stall_secs")? {
                        Value::Null => 0.0,
                        other => other.as_f64()?,
                    },
                });
            }
            let errors = sv
                .at("errors")?
                .as_arr()?
                .iter()
                .map(|e| Ok(e.as_str()?.to_string()))
                .collect::<Result<Vec<_>>>()?;
            let snaps = sv.at("snaps")?.as_u64()?;
            report.stages.insert(
                stage,
                StageReport {
                    stage,
                    frames: sv.at("frames")?.as_u64()?,
                    seq_lo: match sv.at("seq_lo")? {
                        Value::Null => u64::MAX,
                        other => other.as_u64()?,
                    },
                    seq_hi: sv.at("seq_hi")?.as_u64()?,
                    compute_ns: sv.at("compute_ns")?.as_u64()?,
                    encode_ns: sv.at("encode_ns")?.as_u64()?,
                    decode_ns: sv.at("decode_ns")?.as_u64()?,
                    queue_depth: sv.at("queue_depth")?.as_u64()? as u32,
                    complete: sv.at("complete")?.as_bool()?,
                    snaps,
                    missed: sv.at("missed")?.as_u64()?,
                    points,
                    resilience,
                    stripes,
                    errors,
                    seen: BTreeSet::new(),
                    newest: None,
                },
            );
        }
        if let Some(cv) = v.get("coordinator").filter(|c| !matches!(c, Value::Null)) {
            let opt = |key: &str| -> f64 {
                cv.get(key).and_then(|x| x.as_f64().ok()).unwrap_or(0.0)
            };
            report.coordinator = Some(CoordinatorSummary {
                images: cv.at("images").and_then(|x| x.as_u64()).unwrap_or(0),
                microbatches: cv.at("microbatches").and_then(|x| x.as_u64()).unwrap_or(0),
                wall_secs: opt("wall_secs"),
                throughput: opt("throughput"),
                accuracy: opt("accuracy"),
                p50_latency_s: opt("p50_latency_s"),
                p99_latency_s: opt("p99_latency_s"),
                // Absent on reports written before the serving plane —
                // old artifacts keep parsing as single-stream.
                streams: cv
                    .get("streams")
                    .and_then(|a| a.as_arr().ok())
                    .map(|a| {
                        a.iter()
                            .filter_map(|tv| {
                                Some(StreamSummary {
                                    stream: tv.at("stream").ok()?.as_u64().ok()? as u32,
                                    weight: tv
                                        .get("weight")
                                        .and_then(|x| x.as_u64().ok())
                                        .unwrap_or(1) as u32,
                                    frames: tv.at("frames").ok()?.as_u64().ok()?,
                                    stalls: tv
                                        .get("stalls")
                                        .and_then(|x| x.as_u64().ok())
                                        .unwrap_or(0),
                                    p50_latency_s: tv
                                        .get("p50_latency_s")
                                        .and_then(|x| x.as_f64().ok())
                                        .unwrap_or(0.0),
                                    p99_latency_s: tv
                                        .get("p99_latency_s")
                                        .and_then(|x| x.as_f64().ok())
                                        .unwrap_or(0.0),
                                })
                            })
                            .collect()
                    })
                    .unwrap_or_default(),
                errors: cv
                    .get("errors")
                    .and_then(|e| e.as_arr().ok())
                    .map(|a| {
                        a.iter()
                            .filter_map(|e| e.as_str().ok().map(str::to_string))
                            .collect()
                    })
                    .unwrap_or_default(),
            });
        }
        Ok(report)
    }

    /// Human-readable rendering (the `quantpipe report` subcommand).
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = writeln!(s, "== QuantPipe pipeline report ==");
        if let Some(c) = &self.coordinator {
            let _ = writeln!(
                s,
                "coordinator      {} microbatches, {} images, {:.2}s wall, {:.1} img/s, top-1 {:.2}%",
                c.microbatches,
                c.images,
                c.wall_secs,
                c.throughput,
                c.accuracy * 100.0
            );
            let _ = writeln!(
                s,
                "e2e latency      p50 {:.1} ms / p99 {:.1} ms",
                c.p50_latency_s * 1e3,
                c.p99_latency_s * 1e3
            );
            for st in &c.streams {
                let _ = writeln!(
                    s,
                    "stream {:<3}       {} frames (weight {}), {} stalls, p50 {:.1} ms / p99 {:.1} ms",
                    st.stream,
                    st.frames,
                    st.weight,
                    st.stalls,
                    st.p50_latency_s * 1e3,
                    st.p99_latency_s * 1e3
                );
            }
            for e in &c.errors {
                let _ = writeln!(s, "  coordinator failure: {e}");
            }
        }
        if self.dropped > 0 {
            let _ = writeln!(s, "dropped          {} unparseable telemetry records", self.dropped);
        }
        let mut compute_sum_s = 0.0;
        for st in self.stages.values() {
            let status = if st.complete { "complete" } else { "INCOMPLETE (died or final record lost)" };
            let seq_range = if st.seq_lo == u64::MAX {
                format!("seq high-water {}", st.seq_hi)
            } else {
                format!("seq {}..{}", st.seq_lo, st.seq_hi)
            };
            let _ = writeln!(
                s,
                "stage {:<2}         {} frames ({seq_range}), {} windows, {} snapshots ({} lost), {status}",
                st.stage,
                st.frames,
                st.points.len(),
                st.snaps,
                st.missed
            );
            let _ = writeln!(s, "  bits sequence  {:?}", st.bits_sequence());
            let finite: Vec<f64> = st
                .points
                .iter()
                .map(|p| p.bandwidth_bps)
                .filter(|b| b.is_finite())
                .collect();
            if !finite.is_empty() {
                let min = finite.iter().copied().fold(f64::INFINITY, f64::min);
                let max = finite.iter().copied().fold(0.0f64, f64::max);
                let _ = writeln!(
                    s,
                    "  bandwidth      min {:.2} / max {:.2} Mbps over {} measured windows",
                    min / 1e6,
                    max / 1e6,
                    finite.len()
                );
            }
            compute_sum_s += st.mean_compute_s();
            let _ = writeln!(
                s,
                "  per frame      compute {:.3} ms, encode {:.3} ms, decode {:.3} ms (queue depth {} at last flush)",
                st.mean_compute_s() * 1e3,
                per_frame_ms(st.encode_ns, st.frames),
                per_frame_ms(st.decode_ns, st.frames),
                st.queue_depth
            );
            let r = &st.resilience;
            if r.reconnects + r.reaccepts + r.replayed + r.deduped > 0 || r.stall_secs > 0.0 {
                let _ = writeln!(
                    s,
                    "  resilience     {} reconnects / {} re-accepts, {} replayed, {} deduped, {:.2}s stalled",
                    r.reconnects, r.reaccepts, r.replayed, r.deduped, r.stall_secs
                );
            }
            for (i, sp) in st.stripes.iter().enumerate() {
                let _ = writeln!(
                    s,
                    "  stripe {i:<2}      {} frames, {} B, {} reconnects, {:.2}s stalled",
                    sp.frames, sp.bytes, sp.reconnects, sp.stall_secs
                );
            }
            for e in &st.errors {
                let _ = writeln!(s, "  stage failure: {e}");
            }
        }
        for (a, b, short) in self.boundary_shortfalls() {
            if short == 0 {
                let _ = writeln!(s, "boundary {a}->{b}    aligned");
            } else {
                let _ = writeln!(
                    s,
                    "boundary {a}->{b}    stage {b} is {short} microbatches short of stage {a} — the bubble sat here"
                );
            }
        }
        if let Some(c) = &self.coordinator {
            if c.p50_latency_s > 0.0 {
                let wire = (c.p50_latency_s - compute_sum_s).max(0.0);
                let _ = writeln!(
                    s,
                    "attribution      p50 e2e {:.1} ms = {:.1} ms stage compute + {:.1} ms wire/codec/queueing",
                    c.p50_latency_s * 1e3,
                    compute_sum_s * 1e3,
                    wire * 1e3
                );
            }
        }
        s
    }
}

fn per_frame_ms(ns: u64, frames: u64) -> f64 {
    if frames == 0 {
        0.0
    } else {
        ns as f64 / 1e6 / frames as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point(t: f64, stage: usize, bits: u8) -> TimelinePoint {
        TimelinePoint {
            t,
            stage,
            bandwidth_bps: 1e6 * t.max(0.1),
            rate: 100.0,
            bits,
            util: 0.5,
        }
    }

    fn snap(stage: u32, n: u64, last: bool, frames: u64, points: Vec<TimelinePoint>) -> StageSnapshot {
        StageSnapshot {
            stage,
            snap: n,
            last,
            frames,
            seq_lo: frames.saturating_sub(points.len() as u64),
            seq_hi: frames,
            compute_ns: frames * 1_000_000,
            encode_ns: frames * 100_000,
            decode_ns: frames * 50_000,
            queue_depth: 1,
            resilience: ResilienceSummary { reconnects: 1, ..Default::default() },
            stripes: vec![StripeSummary { frames, bytes: frames * 100, ..Default::default() }],
            points,
            errors: vec![],
        }
    }

    #[test]
    fn snapshot_roundtrips_through_bytes() {
        let s = StageSnapshot {
            stage: 2,
            snap: 7,
            last: true,
            frames: 64,
            seq_lo: 60,
            seq_hi: 64,
            compute_ns: 123_456_789,
            encode_ns: 42,
            decode_ns: 7,
            queue_depth: 3,
            resilience: ResilienceSummary {
                reconnects: 2,
                reaccepts: 1,
                replayed: 9,
                deduped: 4,
                stall_secs: 0.75,
            },
            stripes: vec![
                StripeSummary { frames: 30, bytes: 999, reconnects: 1, stall_secs: 0.1 },
                StripeSummary { frames: 34, bytes: 1001, reconnects: 0, stall_secs: 0.0 },
            ],
            points: vec![point(1.0, 2, 32), point(2.0, 2, 8)],
            errors: vec!["link 2 (tcp): send failed".into()],
        };
        let bytes = s.to_bytes();
        let back = StageSnapshot::from_bytes(&bytes).unwrap();
        assert_eq!(back, s);
        assert_eq!(StageSnapshot::peek_id(&bytes), Some((2, 7)));
    }

    #[test]
    fn snapshot_with_infinite_bandwidth_survives_binary_and_json() {
        let mut p = point(1.0, 0, 32);
        p.bandwidth_bps = f64::INFINITY;
        let s = snap(0, 0, true, 4, vec![p]);
        let back = StageSnapshot::from_bytes(&s.to_bytes()).unwrap();
        assert!(back.points[0].bandwidth_bps.is_infinite());
        let mut report = PipelineReport::new();
        report.merge(back);
        let json = report.to_json().to_string_pretty();
        let parsed = Value::parse(&json).unwrap();
        let again = PipelineReport::from_json(&parsed).unwrap();
        assert!(again.stages[&0].points[0].bandwidth_bps.is_infinite());
    }

    #[test]
    fn truncated_or_versioned_garbage_is_an_error_not_a_panic() {
        let s = snap(1, 0, false, 8, vec![point(1.0, 1, 8)]);
        let bytes = s.to_bytes();
        for cut in [0usize, 1, 5, 13, bytes.len() - 1] {
            assert!(StageSnapshot::from_bytes(&bytes[..cut]).is_err(), "cut={cut}");
        }
        let mut wrong = bytes.clone();
        wrong[0] = 99;
        assert!(StageSnapshot::from_bytes(&wrong).is_err());
        assert_eq!(StageSnapshot::peek_id(&wrong), None);
        let mut report = PipelineReport::new();
        report.ingest(&wrong);
        assert_eq!(report.dropped, 1, "garbage is counted, never fatal");
    }

    #[test]
    fn merge_handles_out_of_order_worker_arrival() {
        // Snapshots arrive 2, 0, 1 — counters must come from snap 2, the
        // timeline must still be ascending, nothing double-counted.
        let mut report = PipelineReport::new();
        report.merge(snap(0, 2, true, 30, vec![point(3.0, 0, 2)]));
        report.merge(snap(0, 0, false, 10, vec![point(1.0, 0, 32)]));
        report.merge(snap(0, 1, false, 20, vec![point(2.0, 0, 8)]));
        let st = &report.stages[&0];
        assert_eq!(st.frames, 30, "counters from the newest snapshot");
        assert!(st.complete);
        assert_eq!(st.snaps, 3);
        assert_eq!(st.missed, 0);
        assert_eq!(st.seq_lo, 9, "seq_lo folds the minimum across ALL snapshots");
        assert_eq!(st.seq_hi, 30);
        let ts: Vec<f64> = st.points.iter().map(|p| p.t).collect();
        assert_eq!(ts, vec![1.0, 2.0, 3.0], "timeline must be re-ordered");
        assert_eq!(st.bits_sequence(), vec![32, 8, 2]);
    }

    #[test]
    fn merge_dedups_broadcast_copies() {
        let mut report = PipelineReport::new();
        let s = snap(1, 0, false, 10, vec![point(1.0, 1, 8)]);
        report.merge(s.clone());
        report.merge(s.clone());
        report.merge(s);
        let st = &report.stages[&1];
        assert_eq!(st.snaps, 1);
        assert_eq!(st.points.len(), 1, "duplicate snapshots must not duplicate points");
    }

    #[test]
    fn stage_that_died_mid_run_is_flagged_and_shows_the_bubble() {
        let mut report = PipelineReport::new();
        // Stage 0 finishes its 30 frames; stage 1 dies after 12 and its
        // final record never arrives.
        report.merge(snap(0, 0, false, 15, vec![point(1.0, 0, 8)]));
        report.merge(snap(0, 1, true, 30, vec![point(2.0, 0, 8)]));
        report.merge(snap(1, 0, false, 12, vec![point(1.1, 1, 8)]));
        assert!(report.stages[&0].complete);
        assert!(!report.stages[&1].complete, "no final snapshot = died mid-run");
        assert_eq!(report.boundary_shortfalls(), vec![(0, 1, 18)]);
        let text = report.render();
        assert!(text.contains("INCOMPLETE"), "{text}");
        assert!(text.contains("18 microbatches short"), "{text}");
    }

    #[test]
    fn lost_telemetry_records_are_counted_as_gaps() {
        let mut report = PipelineReport::new();
        report.merge(snap(0, 0, false, 10, vec![]));
        report.merge(snap(0, 3, true, 40, vec![]));
        let st = &report.stages[&0];
        assert_eq!(st.snaps, 2);
        assert_eq!(st.missed, 2, "snaps 1 and 2 were lost in transit");
        assert_eq!(st.frames, 40, "cumulative counters self-heal across the gap");
    }

    #[test]
    fn seq_alignment_across_boundaries() {
        let mut report = PipelineReport::new();
        for stage in 0..3u32 {
            report.merge(snap(stage, 0, true, 24, vec![point(1.0, stage as usize, 8)]));
        }
        assert_eq!(report.stage_count(), 3);
        assert!(report.boundary_shortfalls().iter().all(|&(_, _, d)| d == 0));
        assert!(report.render().contains("aligned"));
    }

    #[test]
    fn json_roundtrip_preserves_the_merged_view() {
        let mut report = PipelineReport::new();
        report.merge(snap(0, 0, true, 24, vec![point(1.0, 0, 32), point(2.0, 0, 8)]));
        report.merge(snap(1, 0, false, 20, vec![point(1.5, 1, 8)]));
        report.coordinator = Some(CoordinatorSummary {
            images: 192,
            microbatches: 24,
            wall_secs: 2.0,
            throughput: 96.0,
            accuracy: 1.0,
            p50_latency_s: 0.012,
            p99_latency_s: 0.04,
            streams: vec![
                StreamSummary {
                    stream: 0,
                    weight: 4,
                    frames: 16,
                    stalls: 9,
                    p50_latency_s: 0.010,
                    p99_latency_s: 0.050,
                },
                StreamSummary {
                    stream: 1,
                    weight: 1,
                    frames: 8,
                    stalls: 0,
                    p50_latency_s: 0.011,
                    p99_latency_s: 0.020,
                },
            ],
            errors: vec![],
        });
        let json = report.to_json().to_string_pretty();
        let back = PipelineReport::from_json(&Value::parse(&json).unwrap()).unwrap();
        assert_eq!(back.stage_count(), 2);
        assert_eq!(back.stages[&0].frames, 24);
        assert!(back.stages[&0].complete);
        assert!(!back.stages[&1].complete);
        assert_eq!(back.stages[&0].points.len(), 2);
        assert_eq!(back.stages[&0].bits_sequence(), vec![32, 8]);
        let c = back.coordinator.as_ref().unwrap();
        assert_eq!(c.microbatches, 24);
        assert!((c.accuracy - 1.0).abs() < 1e-12);
        // The serving plane's per-stream rows survive the round trip…
        assert_eq!(c.streams, report.coordinator.as_ref().unwrap().streams);
        // …and the renderer shows who absorbed the backpressure.
        let text = back.render();
        assert!(text.contains("stage 0"));
        assert!(text.contains("9 stalls"), "{text}");
    }

    #[test]
    fn pre_serving_plane_reports_parse_as_single_stream() {
        // A v1 report written before the `streams` key existed.
        let json = r#"{
            "schema": "quantpipe.pipeline_report.v1",
            "dropped": 0,
            "stages": [],
            "coordinator": {
                "images": 8, "microbatches": 1, "wall_secs": 1.0,
                "throughput": 8.0, "accuracy": 1.0,
                "p50_latency_s": 0.01, "p99_latency_s": 0.02,
                "errors": []
            }
        }"#;
        let back = PipelineReport::from_json(&Value::parse(json).unwrap()).unwrap();
        let c = back.coordinator.as_ref().unwrap();
        assert_eq!(c.microbatches, 1);
        assert!(c.streams.is_empty(), "absent key = classic single-stream run");
    }

    #[test]
    fn relay_dedups_per_hop_but_forwards_unknown_payloads() {
        let mut relay = TelemetryRelay::default();
        let a = snap(0, 0, false, 1, vec![]).to_bytes();
        assert!(relay.offer(a.clone()), "first copy queued");
        assert!(!relay.offer(a.clone()), "broadcast duplicate dropped");
        assert!(relay.offer(vec![0xde, 0xad]), "unparseable payloads pass through");
        let q = relay.drain();
        assert_eq!(q.len(), 2);
        assert!(relay.is_empty());
        assert!(!relay.offer(a), "dedup memory survives the drain");
    }
}
