//! Lightweight metrics: throughput meters, latency histograms, the
//! timeline recorder behind the Fig 5 reproduction, the resilience
//! counters fed by the fault-tolerant link layer
//! ([`crate::net::resilient`]), and the pipeline-wide telemetry that
//! merges every stage's timeline into one run view ([`telemetry`]).

pub mod telemetry;

use crate::util::sync::TrackedMutex;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Live counters a resilient link endpoint updates while it runs. Shared
/// (`Arc`) between the endpoint — which may be moved into a stage/sender
/// thread — and whoever assembles the run report.
#[derive(Debug, Default)]
pub struct ResilienceStats {
    /// Successful redials by the connecting side after a link failure
    /// (the first connect of a session is not a reconnect).
    pub reconnects: AtomicU64,
    /// Successful re-accepts by the listening side after a link failure.
    /// Counted apart from `reconnects` so one outage on a link whose two
    /// ends share a stats block (loopback) still reads as one reconnect.
    pub reaccepts: AtomicU64,
    /// Frames re-sent from the replay buffer after a reconnect handshake.
    pub replayed: AtomicU64,
    /// Duplicate frames (seq already delivered) discarded by the receiver.
    pub deduped: AtomicU64,
    /// Microseconds the *dialing* side spent re-establishing failed
    /// connections — the stall the adaptive controller sees as collapsed
    /// bandwidth (the acceptor's overlapping wait is not double-charged).
    pub stall_us: AtomicU64,
}

impl ResilienceStats {
    /// Consistent-enough copy of the live counters (each load is atomic;
    /// the set is advisory, not transactional).
    pub fn snapshot(&self) -> ResilienceSummary {
        ResilienceSummary {
            reconnects: self.reconnects.load(Ordering::Relaxed),
            reaccepts: self.reaccepts.load(Ordering::Relaxed),
            replayed: self.replayed.load(Ordering::Relaxed),
            deduped: self.deduped.load(Ordering::Relaxed),
            stall_secs: self.stall_us.load(Ordering::Relaxed) as f64 / 1e6,
        }
    }
}

/// Aggregated resilience counters for a finished run (all links, both
/// endpoint roles).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ResilienceSummary {
    /// Successful redials by connecting sides after link failures.
    pub reconnects: u64,
    /// Successful re-accepts by listening sides after link failures.
    pub reaccepts: u64,
    /// Frames re-sent from replay buffers after reconnect handshakes.
    pub replayed: u64,
    /// Duplicate frames discarded by receivers.
    pub deduped: u64,
    /// Seconds dialing sides spent re-establishing failed connections.
    pub stall_secs: f64,
}

impl ResilienceSummary {
    /// Fold another endpoint's counters into this aggregate.
    pub fn merge(&mut self, other: &ResilienceSummary) {
        self.reconnects += other.reconnects;
        self.reaccepts += other.reaccepts;
        self.replayed += other.replayed;
        self.deduped += other.deduped;
        self.stall_secs += other.stall_secs;
    }

    /// Aggregate over every endpoint's live counters.
    pub fn collect<'a>(stats: impl IntoIterator<Item = &'a Arc<ResilienceStats>>) -> Self {
        let mut out = ResilienceSummary::default();
        for s in stats {
            out.merge(&s.snapshot());
        }
        out
    }

    /// JSON object form (non-finite stall maps to `null`).
    pub fn to_json(&self) -> crate::util::json::Value {
        use crate::util::json::Value;
        let mut m = std::collections::BTreeMap::new();
        m.insert("reconnects".into(), Value::Num(self.reconnects as f64));
        m.insert("reaccepts".into(), Value::Num(self.reaccepts as f64));
        m.insert("replayed".into(), Value::Num(self.replayed as f64));
        m.insert("deduped".into(), Value::Num(self.deduped as f64));
        m.insert("stall_secs".into(), Value::num_or_null(self.stall_secs));
        Value::Obj(m)
    }
}

/// Live per-stripe counters a striped boundary updates while it runs —
/// one block per conduit of a [`crate::net::stripe`] link, shared (`Arc`)
/// between the sender thread and whoever assembles the run report.
#[derive(Debug, Default)]
pub struct StripeStats {
    /// Frames this stripe carried (replays included: a replayed frame is
    /// real wire traffic, and for the first frame of a session its only
    /// transmission).
    pub frames: AtomicU64,
    /// Wire bytes this stripe carried.
    pub bytes: AtomicU64,
    /// Successful re-establishments of this stripe after a failure.
    pub reconnects: AtomicU64,
    /// Microseconds spent re-establishing (or failing to re-establish)
    /// this stripe — the per-stripe share of the partial bandwidth
    /// collapse the adaptive controller sees.
    pub stall_us: AtomicU64,
}

impl StripeStats {
    /// Consistent-enough copy of the live counters.
    pub fn snapshot(&self) -> StripeSummary {
        StripeSummary {
            frames: self.frames.load(Ordering::Relaxed),
            bytes: self.bytes.load(Ordering::Relaxed),
            reconnects: self.reconnects.load(Ordering::Relaxed),
            stall_secs: self.stall_us.load(Ordering::Relaxed) as f64 / 1e6,
        }
    }
}

/// One stripe's counters for a finished run.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StripeSummary {
    /// Frames this stripe carried (replays included).
    pub frames: u64,
    /// Wire bytes this stripe carried.
    pub bytes: u64,
    /// Successful re-establishments of this stripe after failures.
    pub reconnects: u64,
    /// Seconds spent re-establishing (or failing to re-establish) it.
    pub stall_secs: f64,
}

impl StripeSummary {
    /// Snapshot every live per-stripe block, preserving stripe order.
    pub fn collect<'a>(stats: impl IntoIterator<Item = &'a Arc<StripeStats>>) -> Vec<Self> {
        stats.into_iter().map(|s| s.snapshot()).collect()
    }

    /// JSON object form (non-finite stall maps to `null`).
    pub fn to_json(&self) -> crate::util::json::Value {
        use crate::util::json::Value;
        let mut m = std::collections::BTreeMap::new();
        m.insert("frames".into(), Value::Num(self.frames as f64));
        m.insert("bytes".into(), Value::Num(self.bytes as f64));
        m.insert("reconnects".into(), Value::Num(self.reconnects as f64));
        m.insert("stall_secs".into(), Value::num_or_null(self.stall_secs));
        Value::Obj(m)
    }

    /// JSON array for a whole boundary (or every striped boundary of a
    /// run, concatenated in link order).
    pub fn list_to_json(list: &[StripeSummary]) -> crate::util::json::Value {
        crate::util::json::Value::Arr(list.iter().map(|s| s.to_json()).collect())
    }
}

/// Exponential-bucket latency histogram (1 µs … ~64 s).
#[derive(Debug, Clone)]
pub struct LatencyHisto {
    buckets: Vec<u64>,
    count: u64,
    sum_ns: u128,
    max_ns: u128,
}

const BUCKETS: usize = 27; // 2^i µs, i in 0..27

impl Default for LatencyHisto {
    fn default() -> Self {
        LatencyHisto { buckets: vec![0; BUCKETS], count: 0, sum_ns: 0, max_ns: 0 }
    }
}

impl LatencyHisto {
    /// Record one observation.
    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros().max(1);
        let idx = (127 - (us as u128).leading_zeros() as usize).min(BUCKETS - 1);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_ns += d.as_nanos();
        self.max_ns = self.max_ns.max(d.as_nanos());
    }

    /// Observations recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Mean observed latency (zero when empty).
    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos((self.sum_ns / self.count as u128) as u64)
    }

    /// Largest observed latency.
    pub fn max(&self) -> Duration {
        Duration::from_nanos(self.max_ns as u64)
    }

    /// Approximate quantile from bucket upper edges.
    pub fn quantile(&self, q: f64) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                return Duration::from_micros(1u64 << (i + 1));
            }
        }
        self.max()
    }
}

/// A point on the Fig 5 timeline: one adaptive window on one link.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TimelinePoint {
    /// Seconds since run start.
    pub t: f64,
    /// Stage index that owns the send link.
    pub stage: usize,
    /// Measured output bandwidth (bits/s).
    pub bandwidth_bps: f64,
    /// Achieved output rate (images/s).
    pub rate: f64,
    /// Bitwidth in effect after this window's decision.
    pub bits: u8,
    /// Link utilization for the window.
    pub util: f64,
}

/// Collects window-by-window state for offline plotting / assertions.
#[derive(Debug, Default)]
pub struct Timeline {
    /// Recorded window points, in push order.
    pub points: Vec<TimelinePoint>,
}

impl Timeline {
    /// Append one window point.
    pub fn push(&mut self, p: TimelinePoint) {
        self.points.push(p);
    }

    /// A shared timeline for the pipeline's writer threads, under the
    /// lock-order-tracked mutex class `"metrics.timeline"`.
    pub fn shared() -> Arc<TrackedMutex<Timeline>> {
        Arc::new(TrackedMutex::new("metrics.timeline", Timeline::default()))
    }

    /// Take the recorded points out of a shared timeline, regardless of
    /// how many `Arc` clones are still alive or whether a panicked writer
    /// poisoned the mutex. `Arc::try_unwrap(..).unwrap_or_default()` —
    /// the obvious spelling — silently returns an *empty* timeline
    /// whenever a thread still holds a clone, losing the whole Fig 5
    /// record; this never does.
    pub fn take_shared(shared: &Arc<TrackedMutex<Timeline>>) -> Timeline {
        std::mem::take(&mut *shared.guard())
    }

    /// CSV dump (t, stage, bandwidth_mbps, rate, bits, util).
    pub fn to_csv(&self) -> String {
        let mut s = String::from("t,stage,bandwidth_mbps,rate,bits,util\n");
        for p in &self.points {
            let bw = if p.bandwidth_bps.is_infinite() { -1.0 } else { p.bandwidth_bps / 1e6 };
            s.push_str(&format!(
                "{:.3},{},{:.2},{:.2},{},{:.3}\n",
                p.t, p.stage, bw, p.rate, p.bits, p.util
            ));
        }
        s
    }

    /// JSON array of window points. An unconstrained link measures
    /// "infinite" bandwidth (see `monitor`); JSON has no Infinity, so a
    /// non-finite bandwidth is *omitted* from its point — the document
    /// must stay parseable for downstream plotting tools.
    pub fn to_json(&self) -> crate::util::json::Value {
        use crate::util::json::Value;
        use std::collections::BTreeMap;
        Value::Arr(
            self.points
                .iter()
                .map(|p| {
                    let mut m = BTreeMap::new();
                    m.insert("t".to_string(), Value::Num(p.t));
                    m.insert("stage".to_string(), Value::Num(p.stage as f64));
                    if p.bandwidth_bps.is_finite() {
                        m.insert("bandwidth_bps".to_string(), Value::Num(p.bandwidth_bps));
                    }
                    m.insert("rate".to_string(), Value::Num(p.rate));
                    m.insert("bits".to_string(), Value::Num(p.bits as f64));
                    m.insert("util".to_string(), Value::Num(p.util));
                    Value::Obj(m)
                })
                .collect(),
        )
    }

    /// Bits in effect at the end of the run for a given stage link.
    pub fn final_bits(&self, stage: usize) -> Option<u8> {
        self.points.iter().rev().find(|p| p.stage == stage).map(|p| p.bits)
    }

    /// Distinct bitwidth sequence (collapsed) for a stage — the Fig 5
    /// "bitwidth track".
    pub fn bits_sequence(&self, stage: usize) -> Vec<u8> {
        let mut out: Vec<u8> = Vec::new();
        for p in self.points.iter().filter(|p| p.stage == stage) {
            if out.last() != Some(&p.bits) {
                out.push(p.bits);
            }
        }
        out
    }
}

/// Simple throughput meter over the whole run.
#[derive(Debug)]
pub struct ThroughputMeter {
    start: Instant,
    items: u64,
}

impl ThroughputMeter {
    /// Start the clock.
    pub fn start() -> Self {
        ThroughputMeter { start: Instant::now(), items: 0 }
    }

    /// Count `n` more items.
    pub fn add(&mut self, n: u64) {
        self.items += n;
    }

    /// Items per second since [`ThroughputMeter::start`].
    pub fn rate(&self) -> f64 {
        self.items as f64 / self.start.elapsed().as_secs_f64().max(1e-9)
    }

    /// Total items counted.
    pub fn items(&self) -> u64 {
        self.items
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histo_quantiles_ordered() {
        let mut h = LatencyHisto::default();
        for i in 1..=1000u64 {
            h.record(Duration::from_micros(i));
        }
        assert_eq!(h.count(), 1000);
        assert!(h.quantile(0.5) <= h.quantile(0.9));
        assert!(h.quantile(0.9) <= h.quantile(0.999));
        assert!(h.mean() > Duration::from_micros(400));
        assert!(h.mean() < Duration::from_micros(600));
    }

    #[test]
    fn timeline_bits_sequence_collapses() {
        let mut t = Timeline::default();
        for (i, bits) in [32u8, 32, 16, 16, 2, 2, 8, 8].iter().enumerate() {
            t.push(TimelinePoint {
                t: i as f64,
                stage: 0,
                bandwidth_bps: 1e6,
                rate: 100.0,
                bits: *bits,
                util: 0.5,
            });
        }
        assert_eq!(t.bits_sequence(0), vec![32, 16, 2, 8]);
        assert_eq!(t.final_bits(0), Some(8));
        assert_eq!(t.final_bits(1), None);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut t = Timeline::default();
        t.push(TimelinePoint { t: 0.5, stage: 1, bandwidth_bps: f64::INFINITY, rate: 10.0, bits: 32, util: 0.0 });
        let csv = t.to_csv();
        assert!(csv.starts_with("t,stage"));
        assert!(csv.contains("-1.00")); // inf encoded as -1
        assert_eq!(csv.lines().count(), 2);
    }

    #[test]
    fn json_timeline_stays_valid_with_infinite_bandwidth() {
        let mut t = Timeline::default();
        t.push(TimelinePoint { t: 0.5, stage: 0, bandwidth_bps: f64::INFINITY, rate: 10.0, bits: 32, util: 0.0 });
        t.push(TimelinePoint { t: 1.0, stage: 0, bandwidth_bps: 5e6, rate: 20.0, bits: 8, util: 0.9 });
        let s = t.to_json().to_string_pretty();
        let back = crate::util::json::Value::parse(&s).unwrap();
        let arr = back.as_arr().unwrap();
        assert!(arr[0].get("bandwidth_bps").is_none(), "{s}");
        assert_eq!(arr[1].at("bandwidth_bps").unwrap().as_f64().unwrap(), 5e6);
        assert_eq!(arr[1].at("bits").unwrap().as_u64().unwrap(), 8);
    }

    #[test]
    fn take_shared_survives_leaked_arc_and_poison() {
        // Regression: a stage thread that leaks its Arc (or dies holding
        // the lock) must not erase the timeline.
        let shared = Timeline::shared();
        shared.guard().push(TimelinePoint {
            t: 1.0,
            stage: 0,
            bandwidth_bps: 1e6,
            rate: 10.0,
            bits: 8,
            util: 0.5,
        });
        let leaked = shared.clone(); // a worker thread still holds this
        let got = Timeline::take_shared(&shared);
        assert_eq!(got.points.len(), 1, "points lost to a leaked Arc");
        drop(leaked);

        // Poisoned by a panicking writer: still recoverable.
        let shared = Timeline::shared();
        let s2 = shared.clone();
        let _ = std::thread::spawn(move || {
            let mut g = s2.guard();
            g.push(TimelinePoint { t: 2.0, stage: 1, bandwidth_bps: 1.0, rate: 1.0, bits: 2, util: 0.0 });
            panic!("poison");
        })
        .join();
        assert_eq!(Timeline::take_shared(&shared).points.len(), 1);
    }

    #[test]
    fn resilience_summary_merges_and_serializes() {
        let a = Arc::new(ResilienceStats::default());
        a.reconnects.store(2, Ordering::Relaxed);
        a.replayed.store(5, Ordering::Relaxed);
        a.stall_us.store(1_500_000, Ordering::Relaxed);
        let b = Arc::new(ResilienceStats::default());
        b.reconnects.store(1, Ordering::Relaxed);
        b.deduped.store(3, Ordering::Relaxed);
        let sum = ResilienceSummary::collect([&a, &b]);
        assert_eq!(sum.reconnects, 3);
        assert_eq!(sum.replayed, 5);
        assert_eq!(sum.deduped, 3);
        assert!((sum.stall_secs - 1.5).abs() < 1e-9);
        let json = sum.to_json().to_string_pretty();
        let back = crate::util::json::Value::parse(&json).unwrap();
        assert_eq!(back.at("reconnects").unwrap().as_u64().unwrap(), 3);
        assert_eq!(back.at("deduped").unwrap().as_u64().unwrap(), 3);
    }

    #[test]
    fn stripe_summary_snapshots_in_order_and_serializes() {
        let a = Arc::new(StripeStats::default());
        a.frames.store(10, Ordering::Relaxed);
        a.bytes.store(5000, Ordering::Relaxed);
        let b = Arc::new(StripeStats::default());
        b.reconnects.store(2, Ordering::Relaxed);
        b.stall_us.store(250_000, Ordering::Relaxed);
        let list = StripeSummary::collect([&a, &b]);
        assert_eq!(list.len(), 2);
        assert_eq!(list[0].frames, 10);
        assert_eq!(list[1].reconnects, 2);
        assert!((list[1].stall_secs - 0.25).abs() < 1e-9);
        let json = StripeSummary::list_to_json(&list).to_string_pretty();
        let back = crate::util::json::Value::parse(&json).unwrap();
        let arr = back.as_arr().unwrap();
        assert_eq!(arr[0].at("bytes").unwrap().as_u64().unwrap(), 5000);
        assert_eq!(arr[1].at("reconnects").unwrap().as_u64().unwrap(), 2);
    }

    #[test]
    fn throughput_meter() {
        let mut m = ThroughputMeter::start();
        m.add(50);
        std::thread::sleep(Duration::from_millis(100));
        m.add(50);
        let r = m.rate();
        assert!(r > 100.0 && r < 1100.0, "{r}");
        assert_eq!(m.items(), 100);
    }
}
