//! Hot-path invariants of the fused codec and the zero-copy driver path:
//!
//! * fused single-pass encode/decode is byte-/bit-identical to the legacy
//!   two-pass reference across every supported bitwidth, both code-range
//!   conventions (signed symmetric and unsigned asymmetric offsets) and
//!   odd tensor lengths;
//! * multicore encode produces the exact serial byte stream for any
//!   thread count;
//! * the stage-loop buffer discipline (payload recycle + decode pool +
//!   `Tensor::into_data`) performs **zero per-microbatch payload
//!   allocation in steady state** — pointers stay put after warm-up.

use quantpipe::net::frame::Frame;
use quantpipe::quant::codec::Codec;
use quantpipe::quant::{fused, pack, uniform, Method, SUPPORTED_BITS};
use quantpipe::tensor::Tensor;
use quantpipe::util::rng::Rng;

fn activation(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::seed(seed);
    (0..n)
        .map(|i| {
            let v = rng.laplace(0.9) as f32;
            if i % 101 == 0 {
                v * 8.0
            } else {
                v
            }
        })
        .collect()
}

#[test]
fn fused_matrix_bits_offsets_odd_lengths() {
    // SUPPORTED_BITS × {signed, unsigned pack offsets} × odd/edge lengths.
    for bits in SUPPORTED_BITS {
        for n in [0usize, 1, 3, 7, 9, 31, 63, 97, 255, 1000, 1001, 4097] {
            let x = activation(n, 40 + n as u64);
            let params = [
                uniform::symmetric_params(1.2, bits), // zp = 0, lo = -2^(q-1)
                uniform::naive_params(&x, bits),      // zp != 0, lo = 0
            ];
            for p in params {
                let codes = uniform::quantize(&x, &p);
                let legacy_payload = pack::pack_vec(&codes, bits, p.pack_offset());
                let mut fused_payload = Vec::new();
                fused::encode_into(&x, &p, &mut fused_payload);
                assert_eq!(
                    fused_payload, legacy_payload,
                    "encode bits={bits} n={n} lo={}",
                    p.lo
                );

                let unpacked = pack::unpack_vec(&legacy_payload, n, bits, p.pack_offset()).unwrap();
                let legacy_out = uniform::dequantize(&unpacked, &p);
                let mut fused_out = vec![0f32; n];
                fused::decode_into(&legacy_payload, &p, &mut fused_out).unwrap();
                let a: Vec<u32> = legacy_out.iter().map(|v| v.to_bits()).collect();
                let b: Vec<u32> = fused_out.iter().map(|v| v.to_bits()).collect();
                assert_eq!(a, b, "decode bits={bits} n={n} lo={}", p.lo);
            }
        }
    }
}

#[test]
fn parallel_encode_matches_serial_for_every_thread_count() {
    let n = fused::MT_MIN_CHUNK_ELEMS * 4 + 129; // odd tail, several chunks
    let x = activation(n, 7);
    for bits in SUPPORTED_BITS {
        let p = uniform::symmetric_params(1.0, bits);
        let mut serial = Vec::new();
        fused::encode_into(&x, &p, &mut serial);
        for threads in [1usize, 2, 3, 4, 7, 16] {
            let mut par = Vec::new();
            fused::encode_into_mt(&x, &p, threads, &mut par);
            assert_eq!(par, serial, "bits={bits} threads={threads}");
        }
    }
}

#[test]
fn codec_threads_produce_identical_frames() {
    // Through the public Codec API, as the driver uses it.
    let x = activation(fused::MT_MIN_CHUNK_ELEMS * 2, 13);
    let mut serial = Codec::default();
    let mut parallel = Codec::default();
    parallel.set_threads(6);
    for bits in SUPPORTED_BITS {
        let a = serial.encode(&x, Method::Pda, bits).unwrap();
        let b = parallel.encode(&x, Method::Pda, bits).unwrap();
        assert_eq!(a, b, "bits={bits}");
        let (mut da, mut db) = (Vec::new(), Vec::new());
        serial.decode(&a, &mut da).unwrap();
        parallel.decode(&b, &mut db).unwrap();
        assert_eq!(da, db, "bits={bits}");
    }
}

/// The driver stage-loop steady state, reproduced exactly: upstream
/// frames decode into a pooled buffer that moves through the `Tensor`
/// and back ([`Tensor::into_data`]), while consumed frame payloads
/// recycle into the codec for the stage's own encodes. After the first
/// (warm-up) microbatch, no buffer pointer may change — i.e. zero
/// per-microbatch payload reallocation.
#[test]
fn stage_loop_steady_state_reallocates_nothing() {
    let x = activation(4096, 3);
    let mut upstream = Codec::default(); // the sending stage
    let mut codec = Codec::default(); // this stage
    let mut decode_pool: Vec<f32> = Vec::new();
    let mut data_ptr = std::ptr::null::<f32>();
    let mut data_cap = 0usize;
    let mut payload_ptr = std::ptr::null::<u8>();

    for seq in 0..12u64 {
        // Upstream encodes at a fixed bitwidth (recycling its payloads
        // too, as its own stage loop would).
        let enc = upstream.encode(&x, Method::Aciq, 4).unwrap();
        let frame = Frame::new(seq, vec![x.len()], enc);

        // This stage: decode into the pooled buffer, recycle the payload.
        let mut data = std::mem::take(&mut decode_pool);
        codec.decode(&frame.enc, &mut data).unwrap();
        let Frame { shape, enc, .. } = frame;
        codec.recycle(enc);
        let tensor = Tensor::new(data, shape);

        // "Compute", then reclaim the buffer.
        assert_eq!(tensor.elems(), x.len());
        let tp = tensor.data.as_ptr();
        let tc = tensor.data.capacity();
        decode_pool = tensor.into_data();

        // Re-encode through this stage's codec (draws from the recycled
        // payload) as the downstream send would.
        let out = codec.encode(&decode_pool, Method::Aciq, 4).unwrap();
        let out_ptr = out.payload.as_ptr();
        codec.recycle(out);

        if seq >= 1 {
            assert_eq!(tp, data_ptr, "microbatch {seq}: decode buffer reallocated");
            assert_eq!(tc, data_cap, "microbatch {seq}: decode buffer capacity changed");
            assert_eq!(out_ptr, payload_ptr, "microbatch {seq}: encode payload reallocated");
        }
        data_ptr = tp;
        data_cap = tc;
        payload_ptr = out_ptr;
    }
}

#[test]
fn raw_passthrough_bulk_copy_is_lossless_and_reuses_buffers() {
    let x = activation(2048, 19);
    let mut codec = Codec::default();
    let e1 = codec.encode(&x, Method::Pda, 32).unwrap();
    assert!(e1.params.is_none());
    assert_eq!(e1.payload.len(), x.len() * 4);
    let mut out = Vec::new();
    codec.decode(&e1, &mut out).unwrap();
    assert_eq!(out, x);
    let ptr = e1.payload.as_ptr();
    codec.recycle(e1);
    let e2 = codec.encode(&x, Method::Pda, 32).unwrap();
    assert_eq!(e2.payload.as_ptr(), ptr, "passthrough must reuse the recycled payload");
}
