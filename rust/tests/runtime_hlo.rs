//! Integration tests over the PJRT runtime + AOT artifacts:
//! * staged HLO execution == full-model HLO execution (partitioning is
//!   semantics-preserving end to end, through the rust runtime);
//! * the AOT Pallas quantize/dequantize kernels agree with the native
//!   rust implementation code-for-code;
//! * eval-set accuracy through the runtime matches the manifest's
//!   recorded fp32 top-1.
//!
//! Requires `make artifacts`. Without the artifacts (or the PJRT CPU
//! plugin) these tests SKIP with a notice instead of failing the suite;
//! set `QUANTPIPE_REQUIRE_ARTIFACTS=1` (CI with artifacts) to turn a
//! missing setup back into a hard failure.

use quantpipe::data::EvalSet;
use quantpipe::quant::codec::{NativeBackend, QuantBackend};
use quantpipe::quant::{calibrate, Method};
use quantpipe::runtime::{Engine, HloQuantBackend, Manifest};
use quantpipe::tensor::Tensor;
use quantpipe::util::rng::Rng;

fn setup() -> Option<(Manifest, std::path::PathBuf, Engine)> {
    let required = std::env::var_os("QUANTPIPE_REQUIRE_ARTIFACTS").is_some();
    let (manifest, dir) = match Manifest::load(Manifest::default_dir()) {
        Ok(v) => v,
        Err(e) if required => panic!("artifacts required but unavailable: {e:#}"),
        Err(e) => {
            eprintln!("SKIP (artifacts missing — run `make artifacts`): {e:#}");
            return None;
        }
    };
    let engine = match Engine::cpu() {
        Ok(v) => v,
        Err(e) if required => panic!("PJRT CPU client required but unavailable: {e:#}"),
        Err(e) => {
            eprintln!("SKIP (PJRT CPU client unavailable): {e:#}");
            return None;
        }
    };
    Some((manifest, dir, engine))
}

#[test]
fn staged_equals_full_model() {
    let Some((manifest, dir, engine)) = setup() else { return };
    let eval = EvalSet::load(dir.join(&manifest.eval.file)).unwrap();
    let s = manifest.microbatch;
    let img = eval.microbatch(0, s);

    // Full model in one executable.
    let full = engine.load_hlo(dir.join(&manifest.full_model.file)).unwrap();
    let want = full.run_f32(&[&img], &manifest.full_model.out_shape).unwrap();

    // Stage by stage.
    let mut x = img;
    for st in &manifest.stages {
        let exe = engine.load_hlo(dir.join(&st.file)).unwrap();
        x = exe.run_f32(&[&x], &st.out_shape).unwrap();
    }
    assert_eq!(x.shape, want.shape);
    let max_diff = x
        .data
        .iter()
        .zip(&want.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_diff < 2e-3, "staged vs full logits diverge: {max_diff}");
    // And the decisions agree exactly.
    assert_eq!(x.argmax_rows(), want.argmax_rows());
}

#[test]
fn hlo_quant_kernel_matches_native() {
    let Some((manifest, dir, engine)) = setup() else { return };
    let n = manifest.quant.rows * manifest.quant.cols;
    let mut hlo = HloQuantBackend::load(&engine, &dir, &manifest).unwrap();
    let mut native = NativeBackend;
    let mut rng = Rng::seed(5);

    for (i, bits) in [2u8, 4, 6, 8, 16].into_iter().enumerate() {
        let x = rng.laplace_vec(n, 0.5 + i as f32 * 0.3);
        for method in [Method::Naive, Method::Aciq] {
            let p = calibrate(&x, method, bits);
            let mut c_hlo = vec![0i32; n];
            let mut c_nat = vec![0i32; n];
            hlo.quantize(&x, &p, &mut c_hlo).unwrap();
            native.quantize(&x, &p, &mut c_nat).unwrap();
            // Rounding-tie tolerance: a small fraction of values land on
            // exact half-code boundaries (more at high bitwidths where the
            // grid is fine); those may differ by exactly one code.
            let mut diff = 0usize;
            for (a, b) in c_hlo.iter().zip(&c_nat) {
                assert!((a - b).abs() <= 1, "{method:?}@{bits}: code gap {a} vs {b}");
                if a != b {
                    diff += 1;
                }
            }
            assert!(
                (diff as f64) < n as f64 * 5e-3,
                "{method:?}@{bits}: {diff}/{n} codes differ"
            );

            let mut x_hlo = vec![0f32; n];
            let mut x_nat = vec![0f32; n];
            hlo.dequantize(&c_hlo, &p, &mut x_hlo).unwrap();
            native.dequantize(&c_hlo, &p, &mut x_nat).unwrap();
            for (a, b) in x_hlo.iter().zip(&x_nat) {
                assert!((a - b).abs() < 1e-5);
            }
        }
    }
}

#[test]
fn runtime_accuracy_matches_manifest() {
    let Some((manifest, dir, engine)) = setup() else { return };
    let eval = EvalSet::load(dir.join(&manifest.eval.file)).unwrap();
    let s = manifest.microbatch;
    let full = engine.load_hlo(dir.join(&manifest.full_model.file)).unwrap();

    let mut correct = 0usize;
    let mut total = 0usize;
    for i in 0..eval.microbatches(s) {
        let img = eval.microbatch(i, s);
        let logits = full.run_f32(&[&img], &manifest.full_model.out_shape).unwrap();
        let preds = logits.argmax_rows();
        for (p, l) in preds.iter().zip(eval.labels_for(i, s)) {
            if *p == *l as usize {
                correct += 1;
            }
            total += 1;
        }
    }
    let acc = correct as f64 / total as f64;
    assert!(
        (acc - manifest.model.fp32_top1).abs() < 0.01,
        "runtime fp32 accuracy {acc} vs manifest {}",
        manifest.model.fp32_top1
    );
}

#[test]
fn executable_rejects_wrong_shape() {
    let Some((manifest, dir, engine)) = setup() else { return };
    let exe = engine.load_hlo(dir.join(&manifest.stages[0].file)).unwrap();
    let bad = Tensor::zeros(&[1, 2, 3]);
    assert!(exe.run_f32(&[&bad], &manifest.stages[0].out_shape).is_err());
}
