//! End-to-end over REAL localhost TCP sockets — no `SimLink` anywhere on
//! the data path:
//!
//! * the transport-agnostic driver (`LinkSpec::Tcp`) runs a 3-stage
//!   adaptive pipeline across loopback socket boundaries, and the
//!   controller reacts to *measured* socket backpressure from an
//!   artificially throttled writer (a slow downstream reader);
//! * the multi-process worker endpoints (`run_worker`/`run_coordinator`,
//!   one per thread here, one per process in the CLI) move a full
//!   workload through a coordinator → w0 → w1 → w2 → coordinator chain.
//!
//! No AOT artifacts needed: mock stages + synthetic one-hot eval.

use quantpipe::adapt::{AdaptConfig, Policy};
use quantpipe::data::EvalSet;
use quantpipe::net::frame::Frame;
use quantpipe::net::resilient::{resilient_loopback_pair, ResilienceConfig};
use quantpipe::net::stripe::striped_loopback_pair;
use quantpipe::net::tcp;
use quantpipe::net::transport::{FrameRx, FrameTx, LinkSpec};
use quantpipe::pipeline::{
    mock_stage_factory, run, run_coordinator, run_worker, LinkQuant, PipelineSpec, WorkerConfig,
    Workload,
};
use quantpipe::quant::Method;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn eval(count: usize, classes: usize) -> Arc<EvalSet> {
    Arc::new(EvalSet::synthetic_onehot(count, classes))
}

fn tcp_links(n: usize) -> Vec<LinkSpec> {
    (0..n).map(|_| LinkSpec::tcp_loopback().unwrap()).collect()
}

/// Resilience tuning for tests: short budgets, fast backoff.
fn fast_resilience() -> ResilienceConfig {
    ResilienceConfig {
        replay_capacity: 32,
        reconnect_timeout: Duration::from_secs(5),
        initial_timeout: Duration::from_secs(5),
        backoff_base: Duration::from_millis(2),
        backoff_max: Duration::from_millis(20),
        jitter: 0.5,
        hello_timeout: Duration::from_millis(500),
        drain_timeout: Duration::from_secs(5),
        seed: 7,
    }
}

/// One direction of a loopback socket pair (the unused halves drop).
fn pipe() -> (tcp::TcpFrameSender, tcp::TcpFrameReceiver) {
    let ((tx, _a_rx), (_b_tx, rx)) = tcp::loopback_pair().unwrap();
    (tx, rx)
}

#[test]
fn tcp_pipeline_three_stages_quantized_passthrough() {
    // 3 stages, 2 real socket boundaries, 8-bit quantized activations.
    let classes = 16;
    let s = 8;
    let spec = PipelineSpec {
        stages: (0..3)
            .map(|_| mock_stage_factory(1.0, 0.0, vec![s, classes], Duration::ZERO))
            .collect(),
        links: tcp_links(2),
        quant: LinkQuant { method: Method::Aciq, initial_bits: 8, ..Default::default() },
        adapt: None,
        window: 4,
        inflight: 2,
    };
    let report = run(spec, Workload::one_pass(eval(64, classes), s)).unwrap();
    assert_eq!(report.microbatches, 8);
    assert_eq!(report.images, 64);
    assert!(report.errors.is_empty(), "{:?}", report.errors);
    // One-hot rows survive 8-bit ACIQ: argmax intact end to end.
    assert!((report.accuracy - 1.0).abs() < 1e-12, "{report:?}");
    // And the socket really carried 8-bit payloads, not raw f32.
    let raw = (s * classes * 4) as f64;
    assert!(report.link0_mean_bytes < raw, "no compression on the wire: {report:?}");
}

#[test]
fn tcp_backpressure_drives_bits_down() {
    // Stage 1 sleeps per microbatch and stops draining its socket while
    // "computing"; large frames then fill the kernel buffers and stage 0's
    // writes stall. The controller sees that stall as measured bandwidth /
    // rate violation and must shed bits — with no simulated link anywhere.
    let s = 32usize;
    let wide = 4096usize; // 32x4096 f32 = 512 KB per raw frame
    let stall = Duration::from_millis(30);
    let stages = vec![
        mock_stage_factory(1.0, 0.0, vec![s, wide], Duration::ZERO),
        mock_stage_factory(1.0, 0.0, vec![s, wide], stall),
        mock_stage_factory(1.0, 0.0, vec![s, 4], Duration::ZERO),
    ];
    let spec = PipelineSpec {
        stages,
        links: tcp_links(2),
        quant: LinkQuant { method: Method::Aciq, initial_bits: 32, ..Default::default() },
        adapt: Some(AdaptConfig {
            // 5 ms budget per microbatch: far beyond what a ~33 mb/s
            // drain rate sustains at fp32, so compression is required.
            target_rate: 6400.0,
            microbatch: s,
            policy: Policy::Ladder,
            raise_margin: 1.0,
        }),
        window: 4,
        inflight: 2,
    };
    let report = run(spec, Workload::repeat(eval(64, 4), s, 40)).unwrap();
    assert_eq!(report.microbatches, 40);
    assert!(report.errors.is_empty(), "{:?}", report.errors);
    let seq = report.timeline.bits_sequence(0);
    assert!(
        seq.iter().any(|&b| b < 32),
        "controller never reacted to real socket backpressure: {seq:?}"
    );
    // The throttle persists for the whole run, so the run ends compressed.
    assert!(
        report.timeline.final_bits(0).unwrap_or(32) < 32 || seq.iter().any(|&b| b <= 8),
        "reaction too weak: {seq:?}"
    );
}

#[test]
fn worker_chain_over_real_sockets() {
    // The multi-process topology, one endpoint per thread, every boundary
    // a real localhost socket: coordinator → w0 → w1 → w2 → coordinator.
    let classes = 16;
    let s = 8usize;
    let total = 24u64;
    let (c2w0_tx, c2w0_rx) = pipe();
    let (w01_tx, w01_rx) = pipe();
    let (w12_tx, w12_rx) = pipe();
    let (w2c_tx, w2c_rx) = pipe();

    let quant = LinkQuant { method: Method::Aciq, initial_bits: 8, ..Default::default() };
    let cfg = |stage: usize, last: bool| WorkerConfig {
        stage,
        quant,
        adapt: None,
        window: 4,
        microbatch: s,
        quantize_output: !last,
        inflight: 2,
        telemetry: true,
    };
    let (cfg0, cfg1, cfg2) = (cfg(0, false), cfg(1, false), cfg(2, true));

    let w0 = std::thread::spawn(move || {
        run_worker(
            mock_stage_factory(1.0, 0.0, vec![s, classes], Duration::ZERO),
            cfg0,
            Box::new(c2w0_rx),
            Box::new(w01_tx),
        )
    });
    let w1 = std::thread::spawn(move || {
        run_worker(
            mock_stage_factory(1.0, 0.0, vec![s, classes], Duration::ZERO),
            cfg1,
            Box::new(w01_rx),
            Box::new(w12_tx),
        )
    });
    let w2 = std::thread::spawn(move || {
        run_worker(
            mock_stage_factory(1.0, 0.0, vec![s, classes], Duration::ZERO),
            cfg2,
            Box::new(w12_rx),
            Box::new(w2c_tx),
        )
    });

    let report = run_coordinator(
        Workload::repeat(eval(64, classes), s, total),
        Box::new(c2w0_tx),
        Box::new(w2c_rx),
    )
    .unwrap();

    assert_eq!(report.microbatches, total, "{report:?}");
    assert_eq!(report.images, total * s as u64);
    assert!(report.errors.is_empty(), "{:?}", report.errors);
    assert!((report.accuracy - 1.0).abs() < 1e-12, "{report:?}");
    assert_eq!(report.latency.count(), total);

    for (i, w) in vec![w0, w1, w2].into_iter().enumerate() {
        let r = w.join().unwrap().unwrap();
        assert_eq!(r.frames, total, "worker {i}");
        assert!(r.errors.is_empty(), "worker {i}: {:?}", r.errors);
    }

    // The acceptance criterion: one PipelineReport with EVERY stage's
    // timeline populated — each worker's snapshots relayed down the
    // chain into the coordinator's return link (plain TCP mode here; the
    // resilient/striped variants are covered below).
    let p = &report.pipeline;
    assert_eq!(p.stage_count(), 3, "every stage must report: {p:?}");
    assert_eq!(p.dropped, 0, "telemetry must parse cleanly: {p:?}");
    for stage in 0..3u32 {
        let st = &p.stages[&stage];
        assert_eq!(st.frames, total, "stage {stage} frame count");
        assert_eq!(st.seq_hi, total, "stage {stage} seq high-water");
        assert!(st.complete, "stage {stage} final snapshot must arrive");
        assert!(
            !st.points.is_empty(),
            "stage {stage} window timeline must be populated (window=4, total=24)"
        );
        assert!(st.errors.is_empty(), "stage {stage}: {:?}", st.errors);
    }
    // Boundary alignment on microbatch seq: a clean run has no bubble.
    assert!(p.boundary_shortfalls().iter().all(|&(_, _, d)| d == 0), "{p:?}");
    // The merged view serializes, parses back, and renders.
    let json = p.to_json().to_string_pretty();
    let back = quantpipe::metrics::telemetry::PipelineReport::from_json(
        &quantpipe::util::json::Value::parse(&json).unwrap(),
    )
    .unwrap();
    assert_eq!(back.stage_count(), 3);
    let text = back.render();
    assert!(text.contains("stage 0") && text.contains("aligned"), "{text}");
}

#[test]
fn resilient_pipeline_survives_mid_stream_socket_kill() {
    // The acceptance scenario: a 3-stage adaptive pipeline over resilient
    // loopback links; link 0's active socket is killed repeatedly for
    // ~150 ms mid-stream. The run must complete with zero microbatch loss
    // or duplication, RunReport must show the reconnects, and the
    // controller must keep running — shedding bits during the outage
    // (the reconnect stall IS the bandwidth signal) instead of aborting.
    let classes = 16;
    let s = 8usize;
    let total = 80u64;
    let link0 = LinkSpec::tcp_loopback_resilient(fast_resilience()).unwrap();
    let link1 = LinkSpec::tcp_loopback_resilient(fast_resilience()).unwrap();
    let stats0 = link0.resilience().unwrap();
    let kill = match &link0 {
        LinkSpec::ResilientTcp(tx, _) => tx.kill_switch(),
        _ => unreachable!(),
    };

    // Kill storm: wait until the link is live, then shoot down every new
    // connection for 150 ms. Each re-establishment lands its stall in the
    // in-flight send's busy time.
    let killer = std::thread::spawn(move || {
        let t0 = Instant::now();
        while !kill.kill() && t0.elapsed() < Duration::from_secs(5) {
            std::thread::sleep(Duration::from_millis(2));
        }
        let storm = Instant::now();
        while storm.elapsed() < Duration::from_millis(150) {
            kill.kill();
            std::thread::sleep(Duration::from_millis(1));
        }
    });

    let spec = PipelineSpec {
        stages: vec![
            mock_stage_factory(1.0, 0.0, vec![s, classes], Duration::ZERO),
            mock_stage_factory(1.0, 0.0, vec![s, classes], Duration::from_millis(2)),
            mock_stage_factory(1.0, 0.0, vec![s, classes], Duration::ZERO),
        ],
        links: vec![link0, link1],
        quant: LinkQuant { method: Method::Aciq, initial_bits: 32, ..Default::default() },
        adapt: Some(AdaptConfig {
            // 4 ms budget per microbatch: satisfied on a healthy loopback
            // (the 2 ms stage bounds steady state), hopeless across a
            // 150 ms outage — the stalled window must shed bits.
            target_rate: 2000.0,
            microbatch: s,
            policy: Policy::Ladder,
            raise_margin: 1.1,
        }),
        window: 4,
        inflight: 2,
    };
    let report = run(spec, Workload::repeat(eval(64, classes), s, total)).unwrap();
    killer.join().unwrap();

    // (1) zero loss / zero duplication end to end.
    assert_eq!(report.microbatches, total, "{report:?}");
    assert_eq!(report.images, total * s as u64);
    assert!(report.errors.is_empty(), "outage must not surface as an error: {:?}", report.errors);
    assert!((report.accuracy - 1.0).abs() < 1e-12, "payload corrupted: {report:?}");
    assert_eq!(report.latency.count(), total);
    // (2) the report records the reconnects (and the stall behind them).
    assert!(
        report.resilience.reconnects >= 1,
        "kill storm must force at least one reconnect: {:?}",
        report.resilience
    );
    assert_eq!(
        report.resilience.reconnects,
        stats0.snapshot().reconnects,
        "report must aggregate the link counters"
    );
    // (3) the controller kept running and shed bits during the outage.
    let seq = report.timeline.bits_sequence(0);
    assert!(
        seq.iter().any(|&b| b < 32),
        "controller never shed bits across the outage: {seq:?}"
    );
}

#[test]
fn resilient_pipeline_clean_shutdown_reports_no_errors() {
    // The FIN/FIN_ACK drain: a clean end of stream must not look like a
    // failure to the resilient receiver (which treats bare EOF as an
    // outage), so a no-fault run ends with zero errors and zero
    // reconnects.
    let classes = 16;
    let s = 8usize;
    let total = 24u64;
    let links: Vec<LinkSpec> = (0..2)
        .map(|_| LinkSpec::tcp_loopback_resilient(fast_resilience()).unwrap())
        .collect();
    let stats: Vec<_> = links.iter().map(|l| l.resilience().unwrap()).collect();
    let spec = PipelineSpec {
        stages: (0..3)
            .map(|_| mock_stage_factory(1.0, 0.0, vec![s, classes], Duration::ZERO))
            .collect(),
        links,
        quant: LinkQuant { method: Method::Aciq, initial_bits: 8, ..Default::default() },
        adapt: None,
        window: 4,
        inflight: 2,
    };
    let report = run(spec, Workload::repeat(eval(64, classes), s, total)).unwrap();
    assert_eq!(report.microbatches, total, "{report:?}");
    assert!(report.errors.is_empty(), "clean FIN drain must not error: {:?}", report.errors);
    assert!((report.accuracy - 1.0).abs() < 1e-12);
    assert_eq!(report.resilience.reconnects, 0, "clean shutdown misread as failure");
    for (i, st) in stats.iter().enumerate() {
        assert_eq!(st.snapshot().reconnects, 0, "link {i} reconnected on a clean run");
    }
}

#[test]
fn striped_pipeline_clean_run_reports_no_errors_and_per_stripe_counters() {
    // A clean 3-stage run over 4-stripe boundaries: every microbatch
    // arrives exactly once and in order even though consecutive frames
    // ride different connections, the FIN/FIN_ACK drain completes with
    // zero errors and zero reconnects, and the report carries per-stripe
    // wire counters (JSON included).
    let classes = 16;
    let s = 8usize;
    let total = 24u64;
    let stripes = 4usize;
    let links: Vec<LinkSpec> = (0..2)
        .map(|_| LinkSpec::tcp_loopback_striped(stripes, fast_resilience()).unwrap())
        .collect();
    let spec = PipelineSpec {
        stages: (0..3)
            .map(|_| mock_stage_factory(1.0, 0.0, vec![s, classes], Duration::ZERO))
            .collect(),
        links,
        quant: LinkQuant { method: Method::Aciq, initial_bits: 8, ..Default::default() },
        adapt: None,
        window: 4,
        inflight: 2,
    };
    let report = run(spec, Workload::repeat(eval(64, classes), s, total)).unwrap();
    assert_eq!(report.microbatches, total, "{report:?}");
    assert!(report.errors.is_empty(), "clean striped drain must not error: {:?}", report.errors);
    assert!((report.accuracy - 1.0).abs() < 1e-12, "loss/dup/corruption across stripes: {report:?}");
    assert_eq!(report.resilience.reconnects, 0, "clean striped run misread as failure");
    assert_eq!(report.stripes.len(), 2 * stripes, "per-stripe counters for both boundaries");
    let carried: u64 = report.stripes.iter().map(|st| st.frames).sum();
    assert!(
        carried >= 2 * total,
        "each boundary must carry every frame on some stripe: {carried} < {}",
        2 * total
    );
    // The machine-readable report includes the per-stripe counters.
    let json = report.to_json().to_string_pretty();
    let back = quantpipe::util::json::Value::parse(&json).unwrap();
    let arr = back.at("stripes").unwrap();
    assert_eq!(arr.as_arr().unwrap().len(), 2 * stripes, "{json}");
}

#[test]
fn striped_pipeline_survives_individual_stripe_kills() {
    // The acceptance scenario: a 3-stage adaptive pipeline whose first
    // boundary is striped over 4 connections; stripe 0 is killed
    // repeatedly for ~300 ms mid-stream. The run must complete with zero
    // microbatch loss or duplication; the report must show the stripe's
    // reconnects; and the controller must shed bits while the stripe is
    // down — the dead stripe's unacked tail jams the cumulative ACK
    // stream, the replay buffer fills, and the blocked sends read as
    // collapsed measured bandwidth.
    let classes = 256; // 8x256 f32 ≈ 8 KB per raw frame
    let s = 8usize;
    let total = 80u64;
    let mut rcfg = fast_resilience();
    rcfg.replay_capacity = 8; // small slack: a jammed stripe blocks the sender quickly
    let link0 = LinkSpec::tcp_loopback_striped(4, rcfg).unwrap();
    let link1 = LinkSpec::tcp_loopback_resilient(fast_resilience()).unwrap();
    let stats0 = link0.resilience().unwrap();
    let per_stripe = link0.stripe_stats().unwrap();
    let kill = match &link0 {
        LinkSpec::Striped(tx, _) => tx.kill_switch_for(0),
        _ => unreachable!(),
    };

    // Kill storm on stripe 0 only: wait until it is live, then shoot down
    // every revival for 300 ms. The other three stripes stay up.
    let killer = std::thread::spawn(move || {
        let t0 = Instant::now();
        while !kill.kill() && t0.elapsed() < Duration::from_secs(5) {
            std::thread::sleep(Duration::from_millis(2));
        }
        let storm = Instant::now();
        while storm.elapsed() < Duration::from_millis(300) {
            kill.kill();
            std::thread::sleep(Duration::from_millis(1));
        }
    });

    let spec = PipelineSpec {
        stages: vec![
            mock_stage_factory(1.0, 0.0, vec![s, classes], Duration::ZERO),
            mock_stage_factory(1.0, 0.0, vec![s, classes], Duration::from_millis(2)),
            mock_stage_factory(1.0, 0.0, vec![s, classes], Duration::ZERO),
        ],
        links: vec![link0, link1],
        quant: LinkQuant { method: Method::Aciq, initial_bits: 32, ..Default::default() },
        adapt: Some(AdaptConfig {
            // 4 ms budget per microbatch: trivially satisfied on healthy
            // loopback stripes, hopeless while the jammed replay buffer
            // blocks sends for tens of ms — those windows must shed.
            target_rate: 2000.0,
            microbatch: s,
            policy: Policy::Ladder,
            raise_margin: 1.1,
        }),
        window: 4,
        inflight: 2,
    };
    let report = run(spec, Workload::repeat(eval(64, classes), s, total)).unwrap();
    killer.join().unwrap();

    // (1) zero loss / zero duplication end to end.
    assert_eq!(report.microbatches, total, "{report:?}");
    assert_eq!(report.images, total * s as u64);
    assert!(report.errors.is_empty(), "stripe outage must not surface as an error: {:?}", report.errors);
    assert!((report.accuracy - 1.0).abs() < 1e-12, "payload corrupted: {report:?}");
    assert_eq!(report.latency.count(), total);
    // (2) the report records the stripe's reconnects, attributed to the
    // killed stripe.
    assert!(
        report.resilience.reconnects >= 1,
        "kill storm must force at least one stripe reconnect: {:?}",
        report.resilience
    );
    assert!(
        per_stripe[0].snapshot().reconnects >= 1,
        "reconnects must be attributed to the killed stripe: {:?}",
        report.stripes
    );
    assert_eq!(
        stats0.snapshot().reconnects,
        report.stripes.iter().map(|st| st.reconnects).sum::<u64>(),
        "the boundary aggregate must equal the per-stripe attribution"
    );
    assert!(
        report.resilience.reconnects >= stats0.snapshot().reconnects,
        "the run report must include the striped boundary's reconnects"
    );
    // (3) the surviving stripes kept carrying traffic.
    let alive: u64 = (1..4).map(|i| per_stripe[i].snapshot().frames).sum();
    assert!(alive > 0, "surviving stripes must carry frames: {:?}", report.stripes);
    // (4) the controller kept running and shed bits while the stripe was
    // down (the jammed boundary reads as collapsed bandwidth).
    let seq = report.timeline.bits_sequence(0);
    assert!(
        seq.iter().any(|&b| b < 32),
        "controller never shed bits across the stripe outage: {seq:?}"
    );
}

#[test]
fn striped_drain_completes_when_stripes_finish_out_of_order() {
    // Direct endpoint test of the striped FIN/FIN_ACK drain: the sender
    // finishes immediately after its last frame, so the FIN races frames
    // still in flight on other stripes (and the receiver only starts
    // reading afterwards). The receiver must hold the FIN_ACK until the
    // shared sequence space is complete, then close cleanly.
    let (mut tx, mut rx) = striped_loopback_pair(3, &fast_resilience()).unwrap();
    let stats = tx.stats();
    let total = 12u64;
    let sender = std::thread::spawn(move || {
        for seq in 0..total {
            let x: Vec<f32> = (0..64).map(|i| (i as f32 + seq as f32).sin()).collect();
            let mut c = quantpipe::quant::codec::Codec::default();
            let enc = c.encode(&x, Method::Aciq, 8).unwrap();
            tx.send(Frame::new(seq, vec![64], enc)).unwrap();
        }
        tx.finish().unwrap(); // FIN goes out while frames sit on 3 conduits
    });
    // First recv completes the handshakes and unblocks the sender…
    assert_eq!(rx.recv().unwrap().unwrap().seq, 0);
    // …then a pause lets every remaining frame (and the FIN) pile up
    // across the 3 conduits' kernel buffers, so the subsequent reads
    // observe maximally out-of-order arrivals with the FIN racing them.
    std::thread::sleep(Duration::from_millis(100));
    for want in 1..total {
        assert_eq!(rx.recv().unwrap().unwrap().seq, want, "reorder across stripes failed");
    }
    assert!(rx.recv().unwrap().is_none(), "FIN must close the striped boundary cleanly");
    sender.join().unwrap();
    assert_eq!(
        stats.snapshot().reconnects,
        0,
        "clean out-of-order drain misread as a failure"
    );
}

#[test]
fn striped_boundary_carries_telemetry_without_perturbing_the_data_plane() {
    // Telemetry on a 3-stripe boundary: records broadcast over every
    // conduit (so the FIN-triggering stream always carries the final
    // snapshot), frames still arrive exactly once and in order, the
    // drain closes cleanly, and the receiver hands back the payloads.
    use quantpipe::metrics::telemetry::StageSnapshot;
    let (mut tx, mut rx) = striped_loopback_pair(3, &fast_resilience()).unwrap();
    let stats = tx.stats();
    let total = 12u64;
    let sender = std::thread::spawn(move || {
        let mut c = quantpipe::quant::codec::Codec::default();
        for seq in 0..total {
            let x: Vec<f32> = (0..64).map(|i| (i as f32 + seq as f32).sin()).collect();
            let enc = c.encode(&x, Method::Aciq, 8).unwrap();
            tx.send(Frame::new(seq, vec![64], enc)).unwrap();
            if seq % 4 == 3 {
                let snap = StageSnapshot {
                    stage: 0,
                    snap: seq / 4,
                    frames: seq + 1,
                    seq_hi: seq + 1,
                    last: seq + 1 == total,
                    ..Default::default()
                };
                tx.send_telemetry(&snap.to_bytes()).unwrap();
            }
        }
        tx.finish().unwrap();
    });
    let mut payloads = Vec::new();
    for want in 0..total {
        assert_eq!(rx.recv().unwrap().unwrap().seq, want, "telemetry reordered the data plane");
        payloads.extend(rx.poll_telemetry());
    }
    assert!(rx.recv().unwrap().is_none(), "drain must still close cleanly");
    payloads.extend(rx.poll_telemetry());
    sender.join().unwrap();
    // Broadcast over 3 stripes means duplicates are expected; distinct
    // snapshot identities must all be present, and the final snapshot
    // must have survived the drain race.
    let mut report = quantpipe::metrics::telemetry::PipelineReport::new();
    for p in &payloads {
        report.ingest(p);
    }
    assert_eq!(report.dropped, 0, "payloads must come through byte-intact");
    let st = &report.stages[&0];
    assert_eq!(st.snaps, 3, "all three snapshots (deduped) must arrive");
    assert!(st.complete, "the final snapshot must beat the FIN on its conduit");
    assert_eq!(st.frames, total);
    let zero = stats.snapshot();
    assert_eq!(zero.reconnects, 0, "telemetry must not destabilize the stripes");
    assert_eq!(zero.deduped, 0, "telemetry must not trigger data-plane replay");
}

#[test]
fn resilient_worker_chain_survives_link_kill() {
    // Multi-process topology over resilient links: coordinator → w0 → w1
    // → w2 → coordinator, with the w0→w1 connection killed mid-run. The
    // workload must arrive complete and the reports must show the
    // recovery.
    let classes = 16;
    let s = 8usize;
    let total = 60u64;
    let (c2w0_tx, c2w0_rx) = resilient_loopback_pair(&fast_resilience()).unwrap();
    let (w01_tx, w01_rx) = resilient_loopback_pair(&fast_resilience()).unwrap();
    let (w12_tx, w12_rx) = resilient_loopback_pair(&fast_resilience()).unwrap();
    let (w2c_tx, w2c_rx) = resilient_loopback_pair(&fast_resilience()).unwrap();
    let kill = w01_tx.kill_switch();

    let quant = LinkQuant { method: Method::Aciq, initial_bits: 8, ..Default::default() };
    let cfg = |stage: usize, last: bool| WorkerConfig {
        stage,
        quant,
        adapt: None,
        window: 4,
        microbatch: s,
        quantize_output: !last,
        inflight: 2,
        telemetry: true,
    };
    let (cfg0, cfg1, cfg2) = (cfg(0, false), cfg(1, false), cfg(2, true));

    let w0 = std::thread::spawn(move || {
        run_worker(
            mock_stage_factory(1.0, 0.0, vec![s, classes], Duration::ZERO),
            cfg0,
            Box::new(c2w0_rx),
            Box::new(w01_tx),
        )
    });
    let w1 = std::thread::spawn(move || {
        run_worker(
            mock_stage_factory(1.0, 0.0, vec![s, classes], Duration::from_millis(2)),
            cfg1,
            Box::new(w01_rx),
            Box::new(w12_tx),
        )
    });
    let w2 = std::thread::spawn(move || {
        run_worker(
            mock_stage_factory(1.0, 0.0, vec![s, classes], Duration::ZERO),
            cfg2,
            Box::new(w12_rx),
            Box::new(w2c_tx),
        )
    });
    let killer = std::thread::spawn(move || {
        let t0 = Instant::now();
        while !kill.kill() && t0.elapsed() < Duration::from_secs(5) {
            std::thread::sleep(Duration::from_millis(2));
        }
    });

    let report = run_coordinator(
        Workload::repeat(eval(64, classes), s, total),
        Box::new(c2w0_tx),
        Box::new(w2c_rx),
    )
    .unwrap();
    killer.join().unwrap();

    assert_eq!(report.microbatches, total, "{report:?}");
    assert!(report.errors.is_empty(), "{:?}", report.errors);
    assert!((report.accuracy - 1.0).abs() < 1e-12, "{report:?}");
    assert_eq!(report.latency.count(), total);

    let mut chain_reconnects = 0;
    for (i, w) in vec![w0, w1, w2].into_iter().enumerate() {
        let r = w.join().unwrap().unwrap();
        assert_eq!(r.frames, total, "worker {i}");
        assert!(r.errors.is_empty(), "worker {i}: {:?}", r.errors);
        chain_reconnects += r.resilience.reconnects;
    }
    assert!(chain_reconnects >= 1, "the killed w0→w1 link must have reconnected");

    // Telemetry survives the outage: the resilient links dedup replayed
    // frames but must still deliver every stage's merged timeline, and
    // the reconnect shows up in the reporting worker's counters.
    let p = &report.pipeline;
    assert_eq!(p.stage_count(), 3, "every stage must report across the kill: {p:?}");
    for stage in 0..3u32 {
        let st = &p.stages[&stage];
        assert_eq!(st.frames, total, "stage {stage}");
        assert!(st.complete, "stage {stage} final snapshot lost");
        assert!(!st.points.is_empty(), "stage {stage} timeline empty");
    }
    let telem_reconnects: u64 = p.stages.values().map(|s| s.resilience.reconnects).sum();
    assert!(
        telem_reconnects >= 1,
        "the reconnect must be visible in the merged telemetry: {p:?}"
    );
}

#[test]
fn reactor_sweeps_every_conduit_and_survives_a_stripe_kill() {
    // The process-wide read reactor owns every conduit's receive side.
    // Kill one of three stripes mid-stream: the transfer must complete
    // with zero loss, duplication, or reorder; the reconnect must be
    // recorded; and the reactor's sweep counter must have moved — if the
    // bytes arrived any other way, a per-conduit reader thread snuck back
    // onto the receive path.
    use quantpipe::net::reactor;
    let swept_before = reactor::global().unwrap().bytes_swept();
    let mut rcfg = fast_resilience();
    rcfg.replay_capacity = 8;
    let (mut tx, mut rx) = striped_loopback_pair(3, &rcfg).unwrap();
    let stats = tx.stats();
    let kill = tx.kill_switch_for(0);
    let total = 40u64;
    let killer = std::thread::spawn(move || {
        let t0 = Instant::now();
        while !kill.kill() && t0.elapsed() < Duration::from_secs(5) {
            std::thread::sleep(Duration::from_millis(2));
        }
    });
    let sender = std::thread::spawn(move || {
        let mut c = quantpipe::quant::codec::Codec::default();
        for seq in 0..total {
            let x: Vec<f32> = (0..256).map(|i| (i as f32 + seq as f32).sin()).collect();
            let enc = c.encode(&x, Method::Aciq, 8).unwrap();
            tx.send(Frame::new(seq, vec![256], enc)).unwrap();
            // Pace the stream so the kill lands with frames in flight.
            std::thread::sleep(Duration::from_millis(1));
        }
        tx.finish().unwrap();
    });
    for want in 0..total {
        assert_eq!(
            rx.recv().unwrap().unwrap().seq,
            want,
            "loss/dup/reorder across the stripe kill"
        );
    }
    assert!(rx.recv().unwrap().is_none(), "FIN must close cleanly after the kill");
    sender.join().unwrap();
    killer.join().unwrap();
    assert!(
        stats.snapshot().reconnects >= 1,
        "the killed stripe must have reconnected: {:?}",
        stats.snapshot()
    );
    let swept_after = reactor::global().unwrap().bytes_swept();
    assert!(
        swept_after > swept_before,
        "reactor swept nothing ({swept_before} → {swept_after}): reads bypassed it"
    );
}

#[test]
fn prepared_frame_buffer_circulates_back_without_a_copy() {
    // Steady-state copy-free regression (transport-layer sibling of
    // stage_loop_steady_state_reallocates_nothing): the serialization
    // buffer handed to send_prepared moves into the replay buffer, the
    // socket write borrows it there, and the receiver's ack retires it
    // into the spare pool — so reclaim_wire() must hand back the exact
    // allocation, pointer-identical, not a copy.
    use quantpipe::net::transport::PreparedFrame;
    let mut rcfg = fast_resilience();
    rcfg.replay_capacity = 4; // ack_every = 1: the receiver acks every frame
    let (mut tx, mut rx) = striped_loopback_pair(1, &rcfg).unwrap();
    let rx_thread = std::thread::spawn(move || {
        assert_eq!(rx.recv().unwrap().unwrap().seq, 0);
        assert!(rx.recv().unwrap().is_none(), "FIN must close the boundary");
    });
    let mut c = quantpipe::quant::codec::Codec::default();
    let x: Vec<f32> = (0..64).map(|i| (i as f32).sin()).collect();
    let enc = c.encode(&x, Method::Aciq, 8).unwrap();
    let frame = Frame::new(0, vec![64], enc);
    let mut wire = Vec::new();
    frame.write_into(&mut wire);
    let ptr = wire.as_ptr() as usize;
    tx.send_prepared(PreparedFrame { seq: 0, wire }).unwrap();
    // The ack rides back on the receiver's cadence; pump until it lands
    // and the replay buffer releases the wire buffer into the spares.
    let deadline = Instant::now() + Duration::from_secs(5);
    let reclaimed = loop {
        tx.pump();
        if let Some(buf) = tx.reclaim_wire() {
            break buf;
        }
        assert!(Instant::now() < deadline, "the ack never released the sent wire buffer");
        std::thread::sleep(Duration::from_millis(1));
    };
    assert_eq!(
        reclaimed.as_ptr() as usize,
        ptr,
        "the wire buffer came back from a different allocation: something copied it"
    );
    tx.finish().unwrap();
    rx_thread.join().unwrap();
}

/// Feed stub that forwards frames into an echo channel, then fails hard.
/// Panics if `send` is ever called again after the injected failure —
/// the coordinator's feed loop must stop at the FIRST hard error instead
/// of spamming one error per remaining microbatch.
struct FlakyFeed {
    sent: u64,
    fail_after: u64,
    echo: std::sync::mpsc::SyncSender<Frame>,
    failed: bool,
}

impl FrameTx for FlakyFeed {
    fn send(&mut self, frame: Frame) -> quantpipe::Result<f64> {
        assert!(!self.failed, "feed loop kept sending after a hard link failure");
        if self.sent >= self.fail_after {
            self.failed = true;
            return Err(
                std::io::Error::new(std::io::ErrorKind::Other, "injected hard feed failure").into(),
            );
        }
        self.sent += 1;
        self.echo.send(frame).expect("echo receiver alive");
        Ok(0.0)
    }

    fn kind(&self) -> &'static str {
        "flaky-stub"
    }
}

/// Return-path stub: hands back whatever the feed echoed, then reports a
/// clean end of stream once the feed side is gone.
struct EchoReturn(std::sync::mpsc::Receiver<Frame>);

impl FrameRx for EchoReturn {
    fn recv(&mut self) -> quantpipe::Result<Option<Frame>> {
        match self.0.recv_timeout(Duration::from_secs(5)) {
            Ok(f) => Ok(Some(f)),
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => Ok(None),
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                panic!("sink never stopped waiting after the feed failed")
            }
        }
    }

    fn kind(&self) -> &'static str {
        "echo-stub"
    }
}

#[test]
fn coordinator_stops_feeding_after_first_hard_send_error() {
    let s = 8usize;
    let classes = 16;
    let (echo_tx, echo_rx) = std::sync::mpsc::sync_channel::<Frame>(16);
    let feed = FlakyFeed { sent: 0, fail_after: 3, echo: echo_tx, failed: false };
    let report = run_coordinator(
        Workload::repeat(eval(64, classes), s, 20),
        Box::new(feed),
        Box::new(EchoReturn(echo_rx)),
    )
    .unwrap();
    // The 3 echoed microbatches came back; the failure is reported once,
    // not once per remaining microbatch, and the sink did not hang
    // waiting for the other 17.
    assert_eq!(report.microbatches, 3, "{report:?}");
    assert_eq!(
        report.errors.len(),
        1,
        "exactly one feed failure expected: {:?}",
        report.errors
    );
    assert!(report.errors[0].contains("feed link failed"), "{:?}", report.errors);
    assert!((report.accuracy - 1.0).abs() < 1e-12, "{report:?}");
}

#[test]
fn worker_reports_upstream_link_failure() {
    // A stream cut mid-frame must surface as a reported failure, not a
    // silent clean shutdown.
    use std::io::Write as _;
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let feeder = std::thread::spawn(move || {
        let mut s = std::net::TcpStream::connect(addr).unwrap();
        s.write_all(&1000u32.to_le_bytes()).unwrap(); // claim 1000 bytes…
        s.write_all(&[0u8; 12]).unwrap(); // …deliver 12, then die
    });
    let (_up_tx, up_rx) = tcp::accept_one(&listener).unwrap();
    feeder.join().unwrap();
    let (down_tx, _down_rx) = pipe();

    let s = 4usize;
    let wcfg = WorkerConfig {
        stage: 0,
        quant: LinkQuant::default(),
        adapt: None,
        window: 2,
        microbatch: s,
        quantize_output: true,
        inflight: 2,
        telemetry: true,
    };
    let report = run_worker(
        mock_stage_factory(1.0, 0.0, vec![s, 4], Duration::ZERO),
        wcfg,
        Box::new(up_rx),
        Box::new(down_tx),
    )
    .unwrap();
    assert_eq!(report.frames, 0);
    assert!(
        report.errors.iter().any(|e| e.contains("upstream link failed")),
        "failure not reported: {:?}",
        report.errors
    );
}
