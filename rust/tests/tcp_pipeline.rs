//! End-to-end over REAL localhost TCP sockets — no `SimLink` anywhere on
//! the data path:
//!
//! * the transport-agnostic driver (`LinkSpec::Tcp`) runs a 3-stage
//!   adaptive pipeline across loopback socket boundaries, and the
//!   controller reacts to *measured* socket backpressure from an
//!   artificially throttled writer (a slow downstream reader);
//! * the multi-process worker endpoints (`run_worker`/`run_coordinator`,
//!   one per thread here, one per process in the CLI) move a full
//!   workload through a coordinator → w0 → w1 → w2 → coordinator chain.
//!
//! No AOT artifacts needed: mock stages + synthetic one-hot eval.

use quantpipe::adapt::{AdaptConfig, Policy};
use quantpipe::data::EvalSet;
use quantpipe::net::tcp;
use quantpipe::net::transport::LinkSpec;
use quantpipe::pipeline::{
    mock_stage_factory, run, run_coordinator, run_worker, LinkQuant, PipelineSpec, WorkerConfig,
    Workload,
};
use quantpipe::quant::Method;
use std::sync::Arc;
use std::time::Duration;

fn eval(count: usize, classes: usize) -> Arc<EvalSet> {
    Arc::new(EvalSet::synthetic_onehot(count, classes))
}

fn tcp_links(n: usize) -> Vec<LinkSpec> {
    (0..n).map(|_| LinkSpec::tcp_loopback().unwrap()).collect()
}

/// One direction of a loopback socket pair (the unused halves drop).
fn pipe() -> (tcp::TcpFrameSender, tcp::TcpFrameReceiver) {
    let ((tx, _a_rx), (_b_tx, rx)) = tcp::loopback_pair().unwrap();
    (tx, rx)
}

#[test]
fn tcp_pipeline_three_stages_quantized_passthrough() {
    // 3 stages, 2 real socket boundaries, 8-bit quantized activations.
    let classes = 16;
    let s = 8;
    let spec = PipelineSpec {
        stages: (0..3)
            .map(|_| mock_stage_factory(1.0, 0.0, vec![s, classes], Duration::ZERO))
            .collect(),
        links: tcp_links(2),
        quant: LinkQuant { method: Method::Aciq, calib_every: 1, initial_bits: 8 },
        adapt: None,
        window: 4,
        inflight: 2,
    };
    let report = run(spec, Workload::one_pass(eval(64, classes), s)).unwrap();
    assert_eq!(report.microbatches, 8);
    assert_eq!(report.images, 64);
    assert!(report.errors.is_empty(), "{:?}", report.errors);
    // One-hot rows survive 8-bit ACIQ: argmax intact end to end.
    assert!((report.accuracy - 1.0).abs() < 1e-12, "{report:?}");
    // And the socket really carried 8-bit payloads, not raw f32.
    let raw = (s * classes * 4) as f64;
    assert!(report.link0_mean_bytes < raw, "no compression on the wire: {report:?}");
}

#[test]
fn tcp_backpressure_drives_bits_down() {
    // Stage 1 sleeps per microbatch and stops draining its socket while
    // "computing"; large frames then fill the kernel buffers and stage 0's
    // writes stall. The controller sees that stall as measured bandwidth /
    // rate violation and must shed bits — with no simulated link anywhere.
    let s = 32usize;
    let wide = 4096usize; // 32x4096 f32 = 512 KB per raw frame
    let stall = Duration::from_millis(30);
    let stages = vec![
        mock_stage_factory(1.0, 0.0, vec![s, wide], Duration::ZERO),
        mock_stage_factory(1.0, 0.0, vec![s, wide], stall),
        mock_stage_factory(1.0, 0.0, vec![s, 4], Duration::ZERO),
    ];
    let spec = PipelineSpec {
        stages,
        links: tcp_links(2),
        quant: LinkQuant { method: Method::Aciq, calib_every: 1, initial_bits: 32 },
        adapt: Some(AdaptConfig {
            // 5 ms budget per microbatch: far beyond what a ~33 mb/s
            // drain rate sustains at fp32, so compression is required.
            target_rate: 6400.0,
            microbatch: s,
            policy: Policy::Ladder,
            raise_margin: 1.0,
        }),
        window: 4,
        inflight: 2,
    };
    let report = run(spec, Workload::repeat(eval(64, 4), s, 40)).unwrap();
    assert_eq!(report.microbatches, 40);
    assert!(report.errors.is_empty(), "{:?}", report.errors);
    let seq = report.timeline.bits_sequence(0);
    assert!(
        seq.iter().any(|&b| b < 32),
        "controller never reacted to real socket backpressure: {seq:?}"
    );
    // The throttle persists for the whole run, so the run ends compressed.
    assert!(
        report.timeline.final_bits(0).unwrap_or(32) < 32 || seq.iter().any(|&b| b <= 8),
        "reaction too weak: {seq:?}"
    );
}

#[test]
fn worker_chain_over_real_sockets() {
    // The multi-process topology, one endpoint per thread, every boundary
    // a real localhost socket: coordinator → w0 → w1 → w2 → coordinator.
    let classes = 16;
    let s = 8usize;
    let total = 24u64;
    let (c2w0_tx, c2w0_rx) = pipe();
    let (w01_tx, w01_rx) = pipe();
    let (w12_tx, w12_rx) = pipe();
    let (w2c_tx, w2c_rx) = pipe();

    let quant = LinkQuant { method: Method::Aciq, calib_every: 1, initial_bits: 8 };
    let cfg = |stage: usize, last: bool| WorkerConfig {
        stage,
        quant,
        adapt: None,
        window: 4,
        microbatch: s,
        quantize_output: !last,
        inflight: 2,
    };
    let (cfg0, cfg1, cfg2) = (cfg(0, false), cfg(1, false), cfg(2, true));

    let w0 = std::thread::spawn(move || {
        run_worker(
            mock_stage_factory(1.0, 0.0, vec![s, classes], Duration::ZERO),
            cfg0,
            Box::new(c2w0_rx),
            Box::new(w01_tx),
        )
    });
    let w1 = std::thread::spawn(move || {
        run_worker(
            mock_stage_factory(1.0, 0.0, vec![s, classes], Duration::ZERO),
            cfg1,
            Box::new(w01_rx),
            Box::new(w12_tx),
        )
    });
    let w2 = std::thread::spawn(move || {
        run_worker(
            mock_stage_factory(1.0, 0.0, vec![s, classes], Duration::ZERO),
            cfg2,
            Box::new(w12_rx),
            Box::new(w2c_tx),
        )
    });

    let report = run_coordinator(
        Workload::repeat(eval(64, classes), s, total),
        Box::new(c2w0_tx),
        Box::new(w2c_rx),
    )
    .unwrap();

    assert_eq!(report.microbatches, total, "{report:?}");
    assert_eq!(report.images, total * s as u64);
    assert!(report.errors.is_empty(), "{:?}", report.errors);
    assert!((report.accuracy - 1.0).abs() < 1e-12, "{report:?}");
    assert_eq!(report.latency.count(), total);

    for (i, w) in vec![w0, w1, w2].into_iter().enumerate() {
        let r = w.join().unwrap().unwrap();
        assert_eq!(r.frames, total, "worker {i}");
        assert!(r.errors.is_empty(), "worker {i}: {:?}", r.errors);
    }
}

#[test]
fn worker_reports_upstream_link_failure() {
    // A stream cut mid-frame must surface as a reported failure, not a
    // silent clean shutdown.
    use std::io::Write as _;
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let feeder = std::thread::spawn(move || {
        let mut s = std::net::TcpStream::connect(addr).unwrap();
        s.write_all(&1000u32.to_le_bytes()).unwrap(); // claim 1000 bytes…
        s.write_all(&[0u8; 12]).unwrap(); // …deliver 12, then die
    });
    let (_up_tx, up_rx) = tcp::accept_one(&listener).unwrap();
    feeder.join().unwrap();
    let (down_tx, _down_rx) = pipe();

    let s = 4usize;
    let wcfg = WorkerConfig {
        stage: 0,
        quant: LinkQuant::default(),
        adapt: None,
        window: 2,
        microbatch: s,
        quantize_output: true,
        inflight: 2,
    };
    let report = run_worker(
        mock_stage_factory(1.0, 0.0, vec![s, 4], Duration::ZERO),
        wcfg,
        Box::new(up_rx),
        Box::new(down_tx),
    )
    .unwrap();
    assert_eq!(report.frames, 0);
    assert!(
        report.errors.iter().any(|e| e.contains("upstream link failed")),
        "failure not reported: {:?}",
        report.errors
    );
}
