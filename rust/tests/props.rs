//! Property-based tests (in-tree harness, see util::prop) over the
//! coordinator invariants: codec/frame roundtrips, pack/unpack identity,
//! controller monotonicity and ladder feasibility, partitioner optimality
//! vs the reference DP, monitor arithmetic, the reliability session
//! layer's exactly-once/in-order delivery under conduit churn, and the
//! serve scheduler's per-stream FIFO/exactly-once/bounded-queue
//! guarantees under random admission/dispatch interleavings.

use quantpipe::adapt::{required_bits_eq2, required_bits_ladder, AdaptConfig, AdaptivePda, Policy};
use quantpipe::monitor::WindowStats;
use quantpipe::net::frame::Frame;
use quantpipe::net::session::{parse_ctrl, RxStep, SessionRx, SessionTx, K_FIN, K_FIN_ACK};
use quantpipe::partition::{partition, partition_dp, CostModel};
use quantpipe::pipeline::{Admission, ServeConfig, ServeScheduler};
use quantpipe::prop_assert;
use quantpipe::quant::codec::Codec;
use quantpipe::quant::{calibrate, pack, uniform, Method, SUPPORTED_BITS};
use quantpipe::util::prop::forall;
use quantpipe::util::rng::Rng;

fn random_tensor(rng: &mut Rng, n: usize) -> Vec<f32> {
    // Mixture family: gaussian bulk, occasional laplace tail, outliers.
    let sigma = rng.range(0.05, 4.0) as f32;
    let mut x = rng.gaussian_vec(n, sigma);
    if rng.f64() < 0.5 {
        let b = rng.range(0.5, 6.0) as f32;
        let extra = rng.laplace_vec(n / 8 + 1, b);
        x.extend(extra);
    }
    if rng.f64() < 0.3 {
        let k = rng.usize(1, 5);
        for _ in 0..k {
            let idx = rng.usize(0, x.len());
            x[idx] = (rng.range(-100.0, 100.0)) as f32;
        }
    }
    x
}

#[test]
fn prop_pack_unpack_identity() {
    forall(60, |rng| {
        let bits = SUPPORTED_BITS[rng.usize(0, SUPPORTED_BITS.len())];
        let signed = rng.f64() < 0.5;
        let lo = if signed { -(1i32 << (bits - 1)) } else { 0 };
        let n = rng.usize(0, 3000);
        let span = 1usize << bits;
        let codes: Vec<i32> = (0..n).map(|_| lo + rng.usize(0, span) as i32).collect();
        let packed = pack::pack_vec(&codes, bits, lo);
        prop_assert!(packed.len() == pack::packed_len(n, bits), "len");
        let back = pack::unpack_vec(&packed, n, bits, lo).unwrap();
        prop_assert!(back == codes, "roundtrip bits={bits} n={n}");
        // Any shorter payload must be a length error, never a short output.
        if !packed.is_empty() {
            prop_assert!(
                pack::unpack_vec(&packed[..packed.len() - 1], n, bits, lo).is_err(),
                "truncated payload accepted bits={bits} n={n}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_codec_roundtrip_error_bound() {
    forall(40, |rng| {
        let n = rng.usize(16, 4000);
        let x = random_tensor(rng, n);
        let bits = SUPPORTED_BITS[rng.usize(0, SUPPORTED_BITS.len())];
        let method = Method::ALL[rng.usize(0, Method::ALL.len())];
        let mut codec = Codec::default();
        let enc = codec.encode(&x, method, bits).unwrap();
        let p = enc.params.unwrap();
        let mut out = Vec::new();
        codec.decode(&enc, &mut out).unwrap();
        prop_assert!(out.len() == x.len(), "len");
        let clip_lo = (p.lo - p.zero_point) * p.scale;
        let clip_hi = (p.hi - p.zero_point) * p.scale;
        for (a, b) in x.iter().zip(&out) {
            if *a > clip_lo && *a < clip_hi {
                prop_assert!(
                    (a - b).abs() <= p.scale * 0.5 + 1e-4,
                    "in-range error bound {method:?}@{bits}: {a} vs {b} (scale {})",
                    p.scale
                );
            } else {
                // Clipped values reconstruct to (near) the clip boundary.
                prop_assert!(
                    *b >= clip_lo - p.scale && *b <= clip_hi + p.scale,
                    "clip reconstruction"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_frame_roundtrip() {
    forall(40, |rng| {
        let n = rng.usize(8, 2000);
        let x = random_tensor(rng, n);
        let bits = [2u8, 4, 6, 8, 16, 32][rng.usize(0, 6)];
        let mut codec = Codec::default();
        let enc = codec.encode(&x, Method::Pda, bits).unwrap();
        let frame = Frame::new(rng.next_u64(), vec![x.len()], enc);
        let back = Frame::from_bytes(&frame.to_bytes()).unwrap();
        prop_assert!(back == frame, "frame roundtrip bits={bits}");
        Ok(())
    });
}

#[test]
fn prop_controller_bits_feasible_and_monotone() {
    forall(100, |rng| {
        let ratio = rng.range(0.01, 40.0);
        let l = required_bits_ladder(ratio);
        let e = required_bits_eq2(ratio);
        // Eq2 at least as aggressive as ladder (skips 6-bit).
        prop_assert!(e <= l, "eq2 {e} > ladder {l} at ratio {ratio}");
        // Feasibility (above the 2-bit floor).
        if l < 32 && ratio <= 16.0 {
            prop_assert!((l as f64) / 32.0 <= 1.0 / ratio + 1e-12, "ladder feasible");
        }
        // Monotonicity: higher ratio never yields more bits.
        let l2 = required_bits_ladder(ratio * rng.range(1.0, 4.0));
        prop_assert!(l2 <= l, "ladder monotone");
        Ok(())
    });
}

#[test]
fn prop_controller_volume_invariance() {
    // The decision must depend on the underlying tensor, not on the
    // bitwidth it happened to be measured at.
    forall(50, |rng| {
        let full_bytes = rng.range(1e4, 1e7);
        let bw = rng.range(1e5, 1e9);
        let target = rng.range(10.0, 2000.0);
        let mk = |cur: u8| {
            let mut c = AdaptivePda::new(AdaptConfig {
                target_rate: target,
                microbatch: 64,
                policy: Policy::Ladder,
                raise_margin: 1.0,
            });
            c.set_bits(cur);
            let w = WindowStats {
                bandwidth_bps: bw,
                rate: f64::INFINITY, // rate satisfied: isolate the Eq.2 path
                mean_bytes: full_bytes * cur as f64 / 32.0,
                microbatches: 50,
                wall_secs: 1.0,
                link_utilization: 1.0,
            };
            c.on_window(&w).bits
        };
        let base = mk(32);
        for cur in [16u8, 8, 6, 4, 2] {
            prop_assert!(mk(cur) == base, "invariance at cur={cur}");
        }
        Ok(())
    });
}

/// The session-layer invariant behind both the resilient link and the
/// striped boundary: under ARBITRARY interleavings of sends, conduit
/// kills, resyncs (HELLO + replay) and ack batches, the receiver delivers
/// every sequence number exactly once and in order, and the sender's
/// replay buffer never exceeds `replay_capacity`. Conduits are modeled as
/// plain FIFOs of serialized frames (a kill drops the in-flight tail —
/// exactly what a dead socket does); no socket types anywhere.
#[test]
fn prop_session_delivers_exactly_once_in_order_under_churn() {
    fn small_frame(seq: u64) -> Vec<u8> {
        let x: Vec<f32> = (0..8).map(|i| (i as f32 + seq as f32).sin()).collect();
        let mut c = Codec::default();
        Frame::new(seq, vec![8], c.encode(&x, Method::Pda, 8).unwrap()).to_bytes()
    }
    forall(30, |rng| {
        let capacity = rng.usize(2, 12);
        let n_conduits = rng.usize(1, 5);
        // A single ordered conduit runs the strict receiver; stripes get
        // a reorder window bounded by the replay capacity.
        let reorder = if n_conduits == 1 { 0 } else { capacity };
        let mut tx = SessionTx::new(capacity);
        let mut rx = SessionRx::new(capacity, reorder);
        // Some(queue) = alive conduit with its in-flight FIFO.
        let mut conduits: Vec<Option<std::collections::VecDeque<Vec<u8>>>> =
            (0..n_conduits).map(|_| Some(Default::default())).collect();
        let mut next_seq = 0u64;
        let mut delivered: Vec<u64> = Vec::new();

        let mut drain_ready = |rx: &mut SessionRx, delivered: &mut Vec<u64>| {
            while let Some(f) = rx.pop_ready() {
                delivered.push(f.seq);
            }
        };
        for _ in 0..rng.usize(30, 150) {
            match rng.usize(0, 100) {
                // Send: record + enqueue on a random alive conduit.
                0..=44 => {
                    if !tx.has_room() {
                        continue; // backpressure: the boundary would block here
                    }
                    let alive: Vec<usize> = (0..n_conduits)
                        .filter(|&i| conduits[i].is_some())
                        .collect();
                    if alive.is_empty() {
                        continue;
                    }
                    let bytes = small_frame(next_seq);
                    prop_assert!(
                        tx.record_send(next_seq, bytes.clone()).is_ok(),
                        "record with room must succeed (seq {next_seq})"
                    );
                    let pick = alive[rng.usize(0, alive.len())];
                    conduits[pick].as_mut().unwrap().push_back(bytes);
                    next_seq += 1;
                }
                // Deliver: pop the head of a random non-empty conduit.
                45..=74 => {
                    let ready: Vec<usize> = (0..n_conduits)
                        .filter(|&i| conduits[i].as_ref().map_or(false, |q| !q.is_empty()))
                        .collect();
                    if ready.is_empty() {
                        continue;
                    }
                    let pick = ready[rng.usize(0, ready.len())];
                    let bytes = conduits[pick].as_mut().unwrap().pop_front().unwrap();
                    let f = Frame::from_bytes(&bytes).unwrap();
                    match rx.on_frame(f) {
                        Ok(RxStep::Delivered) => drain_ready(&mut rx, &mut delivered),
                        Ok(RxStep::Duplicate) | Ok(RxStep::Buffered) => {}
                        Err(e) => prop_assert!(false, "on_frame rejected a legal frame: {e:#}"),
                    }
                }
                // Ack batch (sometimes forced, as after a dedup).
                75..=84 => {
                    if let Some(pos) = rx.ack_due(rng.f64() < 0.5) {
                        tx.on_ack(pos);
                        rx.mark_acked(pos);
                    }
                }
                // Kill: the conduit dies, its in-flight frames are lost.
                85..=92 => {
                    let pick = rng.usize(0, n_conduits);
                    conduits[pick] = None;
                }
                // Resync: a conduit (re)connects — HELLO + replay. The old
                // FIFO is gone either way (a reconnect is a new socket).
                _ => {
                    let pick = rng.usize(0, n_conduits);
                    conduits[pick] = Some(Default::default());
                    let hello = rx.next_expected();
                    prop_assert!(tx.on_hello(hello).is_ok(), "resync at {hello} must be coverable");
                    for bytes in tx.replay_tail() {
                        conduits[pick].as_mut().unwrap().push_back(bytes.to_vec());
                    }
                }
            }
            prop_assert!(
                tx.unacked() <= capacity,
                "replay buffer exceeded capacity: {} > {capacity}",
                tx.unacked()
            );
        }

        // Converge: final resyncs + delivery until everything arrived
        // (every kill is eventually followed by a resync in the real
        // boundary too — that is what the reconnect budget bounds).
        let mut rounds = 0;
        while (delivered.len() as u64) < next_seq {
            rounds += 1;
            prop_assert!(rounds < 64, "drain did not converge: {}/{next_seq}", delivered.len());
            conduits[0] = Some(Default::default());
            prop_assert!(tx.on_hello(rx.next_expected()).is_ok(), "final resync coverable");
            let replay: Vec<Vec<u8>> = tx.replay_tail().map(|b| b.to_vec()).collect();
            for bytes in replay {
                let f = Frame::from_bytes(&bytes).unwrap();
                match rx.on_frame(f) {
                    Ok(RxStep::Delivered) => drain_ready(&mut rx, &mut delivered),
                    Ok(RxStep::Duplicate) | Ok(RxStep::Buffered) => {}
                    Err(e) => prop_assert!(false, "drain on_frame failed: {e:#}"),
                }
            }
            if let Some(pos) = rx.ack_due(true) {
                tx.on_ack(pos);
                rx.mark_acked(pos);
            }
        }
        prop_assert!(
            delivered == (0..next_seq).collect::<Vec<u64>>(),
            "delivery not exactly-once/in-order: {delivered:?} (sent {next_seq})"
        );

        // The drain handshake closes cleanly: FIN at the boundary, the
        // FIN_ACK owed exactly then, and the sender observes it.
        let (kind, end) = parse_ctrl(&tx.fin_record());
        prop_assert!(kind == K_FIN && end == next_seq, "FIN at {end}, sent {next_seq}");
        prop_assert!(rx.on_fin(end).is_ok(), "complete session must accept FIN");
        prop_assert!(rx.fin_due() == Some(end), "FIN_ACK due once everything is in");
        rx.mark_fin_acked();
        tx.apply_ctrl(K_FIN_ACK, end);
        prop_assert!(tx.fin_acked() && rx.finished(), "drain handshake incomplete");
        Ok(())
    });
}

#[test]
fn prop_tiled_codec_roundtrip_across_shapes_widths_and_budgets() {
    use quantpipe::quant::tile::{self, TileCodec, TileConfig, TileView};
    forall(60, |rng| {
        let n = rng.usize(1, 6000);
        let x = random_tensor(rng, n);
        let n = x.len(); // random_tensor may extend past the requested n
        let bits = SUPPORTED_BITS[rng.usize(0, SUPPORTED_BITS.len())];
        let tile_elems = 8 * rng.usize(1, 128);
        let outlier_frac = rng.range(0.0, 0.5);
        let avg_bits = if rng.f64() < 0.4 {
            Some(rng.range(2.0, 8.0) as f32)
        } else {
            None
        };
        let mut tc = TileCodec::new(TileConfig { tile_elems, outlier_frac }, Method::Pda);
        let mut payload = Vec::new();
        tc.encode_into(&x, bits, avg_bits, &mut payload).unwrap();

        // The payload must parse back to a consistent wire view.
        let view = TileView::parse(&payload, n).unwrap();
        let ntiles = n.div_ceil(tile_elems);
        prop_assert!(view.ntiles == ntiles, "ntiles {} != {ntiles}", view.ntiles);
        prop_assert!(view.params.len() == ntiles, "param table length");
        match avg_bits {
            None => prop_assert!(
                view.params.iter().all(|p| p.bits == bits),
                "uniform mode must pin every tile at {bits}"
            ),
            Some(a) => {
                // Budgeted widths come from the {8,6,4,2} ladder and
                // average at or under the clamped budget.
                prop_assert!(
                    view.params.iter().all(|p| [2u8, 4, 6, 8].contains(&p.bits)),
                    "budget widths off-ladder"
                );
                let total: f64 = view
                    .params
                    .iter()
                    .enumerate()
                    .map(|(t, p)| (p.bits as usize * tile_elems.min(n - t * tile_elems)) as f64)
                    .sum();
                let cap = (f64::from(a).clamp(2.0, 8.0) * 256.0).round() / 256.0 * n as f64;
                prop_assert!(total <= cap + 1e-6, "budget blown: {total} > {cap}");
            }
        }

        let mut out = vec![0f32; n];
        tile::decode_into(&payload, &mut out).unwrap();
        for (t, p) in view.params.iter().enumerate() {
            let clip_lo = (p.lo - p.zero_point) * p.scale;
            let clip_hi = (p.hi - p.zero_point) * p.scale;
            let (a, b) = (t * tile_elems, ((t + 1) * tile_elems).min(n));
            for i in a..b {
                if x[i] == out[i] {
                    continue; // outlier side-channel: exact
                }
                if x[i] > clip_lo && x[i] < clip_hi {
                    prop_assert!(
                        (x[i] - out[i]).abs() <= p.scale * 0.5 + 1e-4,
                        "tile {t} in-range error: {} vs {} (scale {})",
                        x[i],
                        out[i],
                        p.scale
                    );
                } else {
                    prop_assert!(
                        out[i] >= clip_lo - p.scale && out[i] <= clip_hi + p.scale,
                        "tile {t} clip reconstruction"
                    );
                }
            }
        }

        // Any truncation must be a decode error, never a short/garbage read.
        if !payload.is_empty() {
            let cut = rng.usize(0, payload.len());
            prop_assert!(
                TileView::parse(&payload[..cut], n).is_err(),
                "truncated tiled payload accepted at {cut}/{}",
                payload.len()
            );
        }
        Ok(())
    });
}

#[test]
fn prop_partition_greedy_matches_dp() {
    forall(30, |rng| {
        let blocks = rng.usize(3, 14);
        let devices = rng.usize(2, 6);
        let block_s: Vec<Vec<f64>> = (0..devices)
            .map(|_| (0..blocks).map(|_| rng.range(0.1, 2.0)).collect())
            .collect();
        let comm: Vec<f64> = (0..blocks).map(|_| rng.range(0.0, 1.0)).collect();
        let costs = CostModel::new(block_s, comm);
        let g = partition(&costs, devices).bottleneck(&costs);
        let d = partition_dp(&costs, devices).bottleneck(&costs);
        // DP may use fewer devices (it optimizes over ≤k); greedy is fixed-k.
        prop_assert!(g >= d - 1e-9, "greedy {g} better than dp {d}?");
        prop_assert!(g <= d * 1.5 + 1e-9, "greedy {g} way worse than dp {d}");
        Ok(())
    });
}

#[test]
fn prop_calibrate_levels_and_range() {
    forall(60, |rng| {
        let n = rng.usize(32, 2000);
        let x = random_tensor(rng, n);
        let bits = SUPPORTED_BITS[rng.usize(0, SUPPORTED_BITS.len())];
        for m in Method::ALL {
            let p = calibrate(&x, m, bits);
            prop_assert!(p.levels() == 1u32 << bits, "{m:?} levels");
            prop_assert!(p.scale > 0.0 && p.scale.is_finite(), "{m:?} scale");
            let codes = uniform::quantize(&x, &p);
            let (lo, hi) = (p.lo as i32, p.hi as i32);
            prop_assert!(
                codes.iter().all(|&c| c >= lo && c <= hi),
                "{m:?} codes in range"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_ds_never_worse_fit() {
    forall(30, |rng| {
        let n = rng.usize(500, 20000);
        let x = random_tensor(rng, n);
        let r = quantpipe::quant::ds_aciq::ds_aciq_b(&x, 2, 100);
        prop_assert!(
            r.fit_mse_star <= r.fit_mse_e + 1e-15,
            "fit regressed: {} -> {}",
            r.fit_mse_e,
            r.fit_mse_star
        );
        Ok(())
    });
}

#[test]
fn prop_serve_scheduler_fifo_exactly_once_bounded() {
    // Random interleavings of K streams x M microbatches through the
    // serve scheduler. Items are tagged (stream << 32 | index) so the
    // dispatch side can detect cross-stream leakage without any shared
    // bookkeeping. Invariants checked on every step: queue occupancy
    // never exceeds the configured depth, a refused offer hands the item
    // back untouched and only happens at exactly-full. Final: every
    // stream's delivery sequence is exactly 0..M in order (per-stream
    // FIFO + exactly-once) and the scheduler drains empty.
    forall(40, |rng| {
        let k = rng.usize(1, 6);
        let m = rng.usize(1, 24) as u64;
        let depth = rng.usize(1, 8);
        let mut sched = ServeScheduler::new(ServeConfig {
            max_streams: k,
            queue_depth: depth,
        })
        .unwrap();
        for _ in 0..k {
            // 0 and >MAX_WEIGHT exercise the fairness clamp.
            let id = sched.open_stream(rng.usize(0, 40) as u32).unwrap();
            prop_assert!((id as usize) < k, "stream id {id} out of range");
        }
        let mut offered = vec![0u64; k];
        let mut delivered: Vec<Vec<u64>> = vec![Vec::new(); k];
        let total = k as u64 * m;
        let mut steps = 0u64;
        while delivered.iter().map(|d| d.len() as u64).sum::<u64>() < total {
            steps += 1;
            prop_assert!(
                steps < 200_000,
                "scheduler did not converge (k={k} m={m} depth={depth})"
            );
            let pending: Vec<usize> = (0..k).filter(|&i| offered[i] < m).collect();
            if !pending.is_empty() && rng.f64() < 0.55 {
                let st = pending[rng.usize(0, pending.len())];
                let item = ((st as u64) << 32) | offered[st];
                match sched.offer(st as u32, item).unwrap() {
                    Admission::Admitted => offered[st] += 1,
                    Admission::Backpressured(back) => {
                        prop_assert!(back == item, "backpressure must return the item");
                        let q = sched.stats()[st].queued;
                        prop_assert!(
                            q == depth,
                            "stream {st} refused at occupancy {q} < depth {depth}"
                        );
                    }
                }
            } else if let Some((st, item)) = sched.next() {
                prop_assert!(
                    (item >> 32) as usize == st as usize,
                    "cross-stream leak: item of stream {} dispatched as stream {st}",
                    item >> 32
                );
                delivered[st as usize].push(item & 0xFFFF_FFFF);
            }
            for row in sched.stats() {
                prop_assert!(
                    row.queued <= depth,
                    "stream {} occupancy {} exceeds depth {depth}",
                    row.stream,
                    row.queued
                );
            }
        }
        for (st, d) in delivered.iter().enumerate() {
            prop_assert!(
                *d == (0..m).collect::<Vec<u64>>(),
                "stream {st} not exactly-once FIFO: got {} items",
                d.len()
            );
        }
        prop_assert!(sched.is_empty(), "drained scheduler still holds items");
        Ok(())
    });
}
