//! Property-based tests (in-tree harness, see util::prop) over the
//! coordinator invariants: codec/frame roundtrips, pack/unpack identity,
//! controller monotonicity and ladder feasibility, partitioner optimality
//! vs the reference DP, monitor arithmetic.

use quantpipe::adapt::{required_bits_eq2, required_bits_ladder, AdaptConfig, AdaptivePda, Policy};
use quantpipe::monitor::WindowStats;
use quantpipe::net::frame::Frame;
use quantpipe::partition::{partition, partition_dp, CostModel};
use quantpipe::prop_assert;
use quantpipe::quant::codec::Codec;
use quantpipe::quant::{calibrate, pack, uniform, Method, SUPPORTED_BITS};
use quantpipe::util::prop::forall;
use quantpipe::util::rng::Rng;

fn random_tensor(rng: &mut Rng, n: usize) -> Vec<f32> {
    // Mixture family: gaussian bulk, occasional laplace tail, outliers.
    let sigma = rng.range(0.05, 4.0) as f32;
    let mut x = rng.gaussian_vec(n, sigma);
    if rng.f64() < 0.5 {
        let b = rng.range(0.5, 6.0) as f32;
        let extra = rng.laplace_vec(n / 8 + 1, b);
        x.extend(extra);
    }
    if rng.f64() < 0.3 {
        let k = rng.usize(1, 5);
        for _ in 0..k {
            let idx = rng.usize(0, x.len());
            x[idx] = (rng.range(-100.0, 100.0)) as f32;
        }
    }
    x
}

#[test]
fn prop_pack_unpack_identity() {
    forall(60, |rng| {
        let bits = SUPPORTED_BITS[rng.usize(0, SUPPORTED_BITS.len())];
        let signed = rng.f64() < 0.5;
        let lo = if signed { -(1i32 << (bits - 1)) } else { 0 };
        let n = rng.usize(0, 3000);
        let span = 1usize << bits;
        let codes: Vec<i32> = (0..n).map(|_| lo + rng.usize(0, span) as i32).collect();
        let packed = pack::pack_vec(&codes, bits, lo);
        prop_assert!(packed.len() == pack::packed_len(n, bits), "len");
        let back = pack::unpack_vec(&packed, n, bits, lo).unwrap();
        prop_assert!(back == codes, "roundtrip bits={bits} n={n}");
        // Any shorter payload must be a length error, never a short output.
        if !packed.is_empty() {
            prop_assert!(
                pack::unpack_vec(&packed[..packed.len() - 1], n, bits, lo).is_err(),
                "truncated payload accepted bits={bits} n={n}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_codec_roundtrip_error_bound() {
    forall(40, |rng| {
        let n = rng.usize(16, 4000);
        let x = random_tensor(rng, n);
        let bits = SUPPORTED_BITS[rng.usize(0, SUPPORTED_BITS.len())];
        let method = Method::ALL[rng.usize(0, Method::ALL.len())];
        let mut codec = Codec::default();
        let enc = codec.encode(&x, method, bits).unwrap();
        let p = enc.params.unwrap();
        let mut out = Vec::new();
        codec.decode(&enc, &mut out).unwrap();
        prop_assert!(out.len() == x.len(), "len");
        let clip_lo = (p.lo - p.zero_point) * p.scale;
        let clip_hi = (p.hi - p.zero_point) * p.scale;
        for (a, b) in x.iter().zip(&out) {
            if *a > clip_lo && *a < clip_hi {
                prop_assert!(
                    (a - b).abs() <= p.scale * 0.5 + 1e-4,
                    "in-range error bound {method:?}@{bits}: {a} vs {b} (scale {})",
                    p.scale
                );
            } else {
                // Clipped values reconstruct to (near) the clip boundary.
                prop_assert!(
                    *b >= clip_lo - p.scale && *b <= clip_hi + p.scale,
                    "clip reconstruction"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_frame_roundtrip() {
    forall(40, |rng| {
        let n = rng.usize(8, 2000);
        let x = random_tensor(rng, n);
        let bits = [2u8, 4, 6, 8, 16, 32][rng.usize(0, 6)];
        let mut codec = Codec::default();
        let enc = codec.encode(&x, Method::Pda, bits).unwrap();
        let frame = Frame::new(rng.next_u64(), vec![x.len()], enc);
        let back = Frame::from_bytes(&frame.to_bytes()).unwrap();
        prop_assert!(back == frame, "frame roundtrip bits={bits}");
        Ok(())
    });
}

#[test]
fn prop_controller_bits_feasible_and_monotone() {
    forall(100, |rng| {
        let ratio = rng.range(0.01, 40.0);
        let l = required_bits_ladder(ratio);
        let e = required_bits_eq2(ratio);
        // Eq2 at least as aggressive as ladder (skips 6-bit).
        prop_assert!(e <= l, "eq2 {e} > ladder {l} at ratio {ratio}");
        // Feasibility (above the 2-bit floor).
        if l < 32 && ratio <= 16.0 {
            prop_assert!((l as f64) / 32.0 <= 1.0 / ratio + 1e-12, "ladder feasible");
        }
        // Monotonicity: higher ratio never yields more bits.
        let l2 = required_bits_ladder(ratio * rng.range(1.0, 4.0));
        prop_assert!(l2 <= l, "ladder monotone");
        Ok(())
    });
}

#[test]
fn prop_controller_volume_invariance() {
    // The decision must depend on the underlying tensor, not on the
    // bitwidth it happened to be measured at.
    forall(50, |rng| {
        let full_bytes = rng.range(1e4, 1e7);
        let bw = rng.range(1e5, 1e9);
        let target = rng.range(10.0, 2000.0);
        let mk = |cur: u8| {
            let mut c = AdaptivePda::new(AdaptConfig {
                target_rate: target,
                microbatch: 64,
                policy: Policy::Ladder,
                raise_margin: 1.0,
            });
            c.set_bits(cur);
            let w = WindowStats {
                bandwidth_bps: bw,
                rate: f64::INFINITY, // rate satisfied: isolate the Eq.2 path
                mean_bytes: full_bytes * cur as f64 / 32.0,
                microbatches: 50,
                wall_secs: 1.0,
                link_utilization: 1.0,
            };
            c.on_window(&w).bits
        };
        let base = mk(32);
        for cur in [16u8, 8, 6, 4, 2] {
            prop_assert!(mk(cur) == base, "invariance at cur={cur}");
        }
        Ok(())
    });
}

#[test]
fn prop_partition_greedy_matches_dp() {
    forall(30, |rng| {
        let blocks = rng.usize(3, 14);
        let devices = rng.usize(2, 6);
        let block_s: Vec<Vec<f64>> = (0..devices)
            .map(|_| (0..blocks).map(|_| rng.range(0.1, 2.0)).collect())
            .collect();
        let comm: Vec<f64> = (0..blocks).map(|_| rng.range(0.0, 1.0)).collect();
        let costs = CostModel::new(block_s, comm);
        let g = partition(&costs, devices).bottleneck(&costs);
        let d = partition_dp(&costs, devices).bottleneck(&costs);
        // DP may use fewer devices (it optimizes over ≤k); greedy is fixed-k.
        prop_assert!(g >= d - 1e-9, "greedy {g} better than dp {d}?");
        prop_assert!(g <= d * 1.5 + 1e-9, "greedy {g} way worse than dp {d}");
        Ok(())
    });
}

#[test]
fn prop_calibrate_levels_and_range() {
    forall(60, |rng| {
        let n = rng.usize(32, 2000);
        let x = random_tensor(rng, n);
        let bits = SUPPORTED_BITS[rng.usize(0, SUPPORTED_BITS.len())];
        for m in Method::ALL {
            let p = calibrate(&x, m, bits);
            prop_assert!(p.levels() == 1u32 << bits, "{m:?} levels");
            prop_assert!(p.scale > 0.0 && p.scale.is_finite(), "{m:?} scale");
            let codes = uniform::quantize(&x, &p);
            let (lo, hi) = (p.lo as i32, p.hi as i32);
            prop_assert!(
                codes.iter().all(|&c| c >= lo && c <= hi),
                "{m:?} codes in range"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_ds_never_worse_fit() {
    forall(30, |rng| {
        let n = rng.usize(500, 20000);
        let x = random_tensor(rng, n);
        let r = quantpipe::quant::ds_aciq::ds_aciq_b(&x, 2, 100);
        prop_assert!(
            r.fit_mse_star <= r.fit_mse_e + 1e-15,
            "fit regressed: {} -> {}",
            r.fit_mse_e,
            r.fit_mse_star
        );
        Ok(())
    });
}
