//! Deterministic interleaving checker: exhaustive schedules over the
//! session protocol (see `src/analysis/schedule.rs`), plus a pinned
//! regression corpus of schedules that were once interesting.
//!
//! Unlike the randomized soak tests, a clean run here is a *proof* over
//! the bounded space: every interleaving of send/deliver/ack/kill/
//! telemetry/partial-write/corruption/HELLO-resync/FIN the model admits
//! was executed and checked.

use quantpipe::analysis::schedule::{Action, BoundaryModel, Bug};
use quantpipe::util::explore::{explore, replay, Bounds};

#[test]
fn exhaustive_single_conduit_drain() {
    // One resilient (unstriped) conduit, strict in-order delivery.
    let m = BoundaryModel::clean(4, 1, 2, 0);
    let cov = explore(&m, Bounds::default()).unwrap_or_else(|v| panic!("{v}"));
    assert!(cov.terminals >= 1, "{cov:?}");
    assert!(cov.transitions > cov.states, "graph, not a tree: {cov:?}");
}

#[test]
fn exhaustive_single_conduit_kill_and_resync() {
    // A conduit death with frames and acks in flight, then the HELLO
    // resync + replay. Every loss point is explored.
    let m = BoundaryModel::clean(3, 1, 2, 1);
    let cov = explore(&m, Bounds::default()).unwrap_or_else(|v| panic!("{v}"));
    assert!(cov.terminals >= 1, "{cov:?}");
}

#[test]
fn exhaustive_striped_boundary() {
    // Two conduits sharing one sequence space: frames race, FIN can
    // overtake data on the other stripe, the reorder window absorbs it.
    let m = BoundaryModel::clean(3, 2, 4, 0);
    let cov = explore(&m, Bounds { max_depth: 64, max_states: 1 << 21 })
        .unwrap_or_else(|v| panic!("{v}"));
    assert!(cov.terminals >= 1, "{cov:?}");
}

#[test]
fn exhaustive_striped_boundary_with_kill() {
    // The full gauntlet: striping + a kill, replay crossing stripes.
    let m = BoundaryModel::clean(2, 2, 4, 1);
    let cov = explore(&m, Bounds { max_depth: 64, max_states: 1 << 21 })
        .unwrap_or_else(|v| panic!("{v}"));
    assert!(cov.terminals >= 1, "{cov:?}");
}

#[test]
fn exhaustive_two_stream_serving_with_kill() {
    // The serving plane's stream axis: 2 client streams race for the
    // global sequence space on one session while a conduit dies and
    // resyncs. Every stream-to-seq assignment × every loss point must
    // deliver exactly once, in order, with every stream tag intact
    // (the demux invariant is checked at each delivery).
    let m = BoundaryModel::serving(2, 1, 2, 1, 2);
    let cov = explore(&m, Bounds::default()).unwrap_or_else(|v| panic!("{v}"));
    assert!(cov.terminals >= 1, "{cov:?}");
}

#[test]
fn checker_rejects_ack_overshoot() {
    // Self-test: a protocol that acks one past the delivery point must
    // be caught (the overshoot trims an undelivered frame, a kill then
    // loses it for good).
    let m = BoundaryModel {
        total: 2,
        conduits: 1,
        capacity: 2,
        kills: 1,
        tele: 0,
        truncs: 0,
        corrupts: 0,
        streams: 1,
        bug: Some(Bug::AckOvershoot),
    };
    let v = explore(&m, Bounds::default()).expect_err("overshoot must be found");
    assert!(!v.trace.is_empty(), "violation must carry its schedule:\n{v}");
}

#[test]
fn checker_rejects_skipped_replay() {
    let m = BoundaryModel {
        total: 2,
        conduits: 1,
        capacity: 2,
        kills: 1,
        tele: 0,
        truncs: 0,
        corrupts: 0,
        streams: 1,
        bug: Some(Bug::SkipReplay),
    };
    explore(&m, Bounds::default()).expect_err("lost replay must be found");
}

// ---------------------------------------------------------------------------
// Regression corpus: schedules pinned from exploration. Each replays a
// specific ordering end to end and asserts the final state, so a future
// protocol change that breaks one of these orderings fails with the
// exact schedule in hand.
// ---------------------------------------------------------------------------

#[test]
fn corpus_plain_drain() {
    let m = BoundaryModel::clean(1, 1, 1, 0);
    let end = replay(
        &m,
        &[
            Action::Send(0),
            Action::DeliverUp(0),
            Action::EmitAck(0),
            Action::DeliverDown(0),
            Action::SendFin(0),
            Action::DeliverUp(0),
            Action::EmitFinAck(0),
            Action::DeliverDown(0),
        ],
    )
    .unwrap_or_else(|v| panic!("{v}"));
    assert_eq!(end.delivered(), &[0]);
    assert!(end.tx().fin_acked() && end.rx().finished());
}

#[test]
fn corpus_kill_with_frame_in_flight_then_resync() {
    // Frame 1 dies on the wire; the reconnect HELLO replays it.
    let m = BoundaryModel::clean(2, 1, 2, 1);
    let end = replay(
        &m,
        &[
            Action::Send(0),
            Action::Send(0),
            Action::DeliverUp(0), // frame 0 delivered
            Action::EmitAck(0),
            Action::Kill(0),      // frame 1 + the ack die in flight
            Action::Reconnect(0), // HELLO(1) → replay of frame 1
            Action::DeliverUp(0), // frame 1 delivered
            Action::EmitAck(0),
            Action::DeliverDown(0),
            Action::SendFin(0),
            Action::DeliverUp(0),
            Action::EmitFinAck(0),
            Action::DeliverDown(0),
        ],
    )
    .unwrap_or_else(|v| panic!("{v}"));
    assert_eq!(end.delivered(), &[0, 1], "the killed frame must be recovered by replay");
    assert!(end.tx().fin_acked());
}

#[test]
fn corpus_fin_overtakes_data_on_other_stripe() {
    // Striped boundary: FIN rides stripe 1 and arrives before frame 1
    // (still in flight on stripe 0). FIN_ACK must be held until the
    // stripe race resolves.
    let m = BoundaryModel::clean(2, 2, 4, 0);
    let end = replay(
        &m,
        &[
            Action::Send(0),      // frame 0 on stripe 0
            Action::Send(0),      // frame 1 on stripe 0
            Action::DeliverUp(0), // frame 0 delivered
            Action::SendFin(1),   // FIN races ahead on stripe 1
            Action::DeliverUp(1), // FIN(2) arrives before frame 1
            Action::DeliverUp(0), // frame 1 lands; FIN_ACK now unblocked
            Action::EmitFinAck(1),
            Action::DeliverDown(1),
            Action::EmitAck(0),
            Action::DeliverDown(0),
        ],
    )
    .unwrap_or_else(|v| panic!("{v}"));
    assert_eq!(end.delivered(), &[0, 1]);
    assert!(end.tx().fin_acked() && end.rx().finished());
}

#[test]
fn corpus_hello_covers_lost_ack() {
    // The ack dies with the conduit, but the reconnect HELLO carries the
    // receiver's cumulative position, so nothing needs replaying.
    let m = BoundaryModel::clean(1, 1, 2, 1);
    let end = replay(
        &m,
        &[
            Action::Send(0),
            Action::DeliverUp(0), // frame 0 delivered
            Action::EmitAck(0),   // ack queued…
            Action::Kill(0),      // …and lost with the conduit
            Action::Reconnect(0), // HELLO(1) already covers frame 0: no replay
            Action::SendFin(0),
            Action::DeliverUp(0),
            Action::EmitFinAck(0),
            Action::DeliverDown(0),
        ],
    )
    .unwrap_or_else(|v| panic!("{v}"));
    assert_eq!(end.delivered(), &[0], "exactly once despite the lost ack");
    assert!(end.tx().fin_acked());
}

#[test]
fn corpus_truncated_write_loses_tail_then_resyncs() {
    // A telemetry record rides between two data frames when the write is
    // cut off mid-record: frame 0 and the telemetry land, frame 1 (the
    // partial record) is lost with the conduit. The reconnect HELLO
    // carries the receiver's position and exactly the lost frame replays.
    let m = BoundaryModel {
        total: 2,
        conduits: 1,
        capacity: 2,
        kills: 0,
        tele: 1,
        truncs: 1,
        corrupts: 0,
        streams: 1,
        bug: None,
    };
    let end = replay(
        &m,
        &[
            Action::Send(0),          // frame 0
            Action::SendTelemetry(0), // telemetry between the frames
            Action::Send(0),          // frame 1
            Action::TruncateUp(0),    // frame 1 is the partial record
            Action::Reconnect(0),     // HELLO(1) → replay of frame 1 only
            Action::DeliverUp(0),     // frame 1 delivered
            Action::EmitAck(0),
            Action::DeliverDown(0),
            Action::SendFin(0),
            Action::DeliverUp(0),
            Action::EmitFinAck(0),
            Action::DeliverDown(0),
        ],
    )
    .unwrap_or_else(|v| panic!("{v}"));
    assert_eq!(end.delivered(), &[0, 1], "the truncated frame must be recovered by replay");
    assert!(end.tx().fin_acked() && end.rx().finished());
}

#[test]
fn corpus_corrupt_frame_kills_conduit_then_resyncs() {
    // Frame 1 is corrupted on the wire: the receiver's CRC check rejects
    // it and drops the conduit as desynced. The reconnect HELLO carries
    // the receiver's position and exactly the corrupted frame replays —
    // the same recovery path the chaos shaper's byte flips exercise over
    // real sockets in tests/chaos_soak.rs.
    let m = BoundaryModel {
        total: 2,
        conduits: 1,
        capacity: 2,
        kills: 0,
        tele: 0,
        truncs: 0,
        corrupts: 1,
        streams: 1,
        bug: None,
    };
    let end = replay(
        &m,
        &[
            Action::Send(0),
            Action::DeliverUp(0), // frame 0 delivered clean
            Action::EmitAck(0),
            Action::DeliverDown(0),
            Action::Send(0),      // frame 1…
            Action::CorruptUp(0), // …fails its CRC check; conduit dies
            Action::Reconnect(0), // HELLO(1) → replay of frame 1 only
            Action::DeliverUp(0), // frame 1 delivered
            Action::EmitAck(0),
            Action::DeliverDown(0),
            Action::SendFin(0),
            Action::DeliverUp(0),
            Action::EmitFinAck(0),
            Action::DeliverDown(0),
        ],
    )
    .unwrap_or_else(|v| panic!("{v}"));
    assert_eq!(end.delivered(), &[0, 1], "the corrupted frame must be recovered by replay");
    assert!(end.tx().fin_acked() && end.rx().finished());
}

#[test]
fn corpus_two_streams_survive_kill_and_resync_without_leakage() {
    // The serving-plane pin: two interleaved streams share the session's
    // global sequence space; stream 0's frame dies on the wire with the
    // conduit and rides the HELLO resync + replay path back. Demux must
    // survive the round trip — the replayed frame still carries stream
    // 0's tag, and the earlier stream-1 frame was never re-labelled.
    let m = BoundaryModel::serving(2, 1, 2, 1, 2);
    let end = replay(
        &m,
        &[
            Action::SendOn(0, 1), // stream 1 claims global seq 0
            Action::SendOn(0, 0), // stream 0 claims global seq 1
            Action::DeliverUp(0), // seq 0 delivered, tagged stream 1
            Action::EmitAck(0),   // ack queued…
            Action::Kill(0),      // …and lost, with seq 1 still in flight
            Action::Reconnect(0), // HELLO(1) → replay of seq 1, tag intact
            Action::DeliverUp(0), // seq 1 delivered, still tagged stream 0
            Action::EmitAck(0),
            Action::DeliverDown(0),
            Action::SendFin(0),
            Action::DeliverUp(0),
            Action::EmitFinAck(0),
            Action::DeliverDown(0),
        ],
    )
    .unwrap_or_else(|v| panic!("{v}"));
    assert_eq!(end.delivered(), &[0, 1], "both streams' frames recovered exactly once");
    assert_eq!(
        end.delivered_tags(),
        &[1, 0],
        "stream tags must survive the kill + HELLO resync"
    );
    assert!(end.tx().fin_acked() && end.rx().finished());
}

#[test]
fn corpus_replay_duplicates_a_parked_frame() {
    // Striped boundary: frame 1 is parked in the reorder window when
    // stripe 0 dies with frame 0. The session-scoped replay re-sends
    // both unacked frames; the re-sent frame 1 is a duplicate, which the
    // receiver drops and answers with a forced resync ack.
    let m = BoundaryModel::clean(2, 2, 4, 1);
    let end = replay(
        &m,
        &[
            Action::Send(0),      // frame 0 on stripe 0
            Action::Send(1),      // frame 1 on stripe 1
            Action::DeliverUp(1), // frame 1 parked (gap: frame 0 missing)
            Action::Kill(0),      // frame 0 dies in flight
            Action::Reconnect(0), // HELLO(0) → replay of frames 0 AND 1
            Action::DeliverUp(0), // frame 0 lands; both deliver in order
            Action::DeliverUp(0), // replayed frame 1 is a duplicate → force-ack
            Action::DeliverDown(0),
            Action::SendFin(0),
            Action::DeliverUp(0),
            Action::EmitFinAck(0),
            Action::DeliverDown(0),
        ],
    )
    .unwrap_or_else(|v| panic!("{v}"));
    assert_eq!(end.delivered(), &[0, 1], "exactly once, in order, despite the duplicate");
    assert!(end.tx().fin_acked() && end.rx().finished());
}
