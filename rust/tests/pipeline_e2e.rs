//! End-to-end integration: the full rust pipeline (HLO stages + shaped
//! links + codec + controller) over the real eval workload.
//!
//! Requires `make artifacts`. Without the artifacts these tests SKIP
//! with a notice instead of failing the suite; set
//! `QUANTPIPE_REQUIRE_ARTIFACTS=1` to turn that back into a hard failure.

use quantpipe::adapt::{AdaptConfig, Policy};
use quantpipe::benchkit::hlo_spec;
use quantpipe::config::Config;
use quantpipe::data::EvalSet;
use quantpipe::net::mbps;
use quantpipe::net::trace::BandwidthTrace;
use quantpipe::pipeline::{run, LinkQuant, Workload};
use quantpipe::quant::Method;
use quantpipe::runtime::Manifest;
use std::sync::Arc;

fn setup() -> Option<(Manifest, std::path::PathBuf, Arc<EvalSet>, Config)> {
    let (manifest, dir) = match Manifest::load(Manifest::default_dir()) {
        Ok(v) => v,
        Err(e) if std::env::var_os("QUANTPIPE_REQUIRE_ARTIFACTS").is_some() => {
            panic!("artifacts required but unavailable: {e:#}")
        }
        Err(e) => {
            eprintln!("SKIP (artifacts missing — run `make artifacts`): {e:#}");
            return None;
        }
    };
    let eval = Arc::new(EvalSet::load(dir.join(&manifest.eval.file)).unwrap());
    Some((manifest, dir, eval, Config::default()))
}

#[test]
fn fp32_pipeline_matches_manifest_accuracy() {
    let Some((manifest, dir, eval, cfg)) = setup() else { return };
    let spec = hlo_spec(
        &manifest, &dir, &cfg,
        vec![BandwidthTrace::unlimited(); manifest.stages.len() - 1],
        LinkQuant { method: Method::Pda, initial_bits: 32, ..Default::default() },
        None,
    );
    let report = run(spec, Workload::one_pass(eval, manifest.microbatch)).unwrap();
    assert!(
        (report.accuracy - manifest.model.fp32_top1).abs() < 0.01,
        "pipeline fp32 {} vs manifest {}",
        report.accuracy,
        manifest.model.fp32_top1
    );
    assert_eq!(report.images as usize, manifest.eval.count);
}

#[test]
fn eight_bit_pda_keeps_accuracy_and_compresses() {
    let Some((manifest, dir, eval, cfg)) = setup() else { return };
    let spec = hlo_spec(
        &manifest, &dir, &cfg,
        vec![BandwidthTrace::unlimited(); manifest.stages.len() - 1],
        LinkQuant { method: Method::Pda, initial_bits: 8, ..Default::default() },
        None,
    );
    let report = run(spec, Workload::one_pass(eval, manifest.microbatch)).unwrap();
    assert!(
        report.accuracy > manifest.model.fp32_top1 - 0.03,
        "8-bit accuracy dropped too far: {}",
        report.accuracy
    );
    // ~4x compression on the wire (payload; header adds a little).
    let full = manifest.activation_shape.iter().product::<usize>() * 4;
    assert!(
        report.link0_mean_bytes < full as f64 / 3.5,
        "8-bit should compress ~4x: {} vs {}",
        report.link0_mean_bytes,
        full
    );
}

#[test]
fn adaptive_run_recovers_bits_on_recovery() {
    let Some((manifest, dir, eval, mut cfg)) = setup() else { return };
    cfg.adapt.window = 5;
    let n_links = manifest.stages.len() - 1;
    // Capacity step: tight for ~half the run, then unlimited.
    let act_bits = manifest.activation_shape.iter().product::<usize>() as f64 * 32.0;
    // Budget that requires ≈8x compression at target rate 0.5 ceiling…
    // use a rough compute estimate instead of hardcoding: run 10 mb first.
    let ceiling = run(
        hlo_spec(
            &manifest, &dir, &cfg,
            vec![BandwidthTrace::unlimited(); n_links],
            LinkQuant { method: Method::Pda, initial_bits: 32, ..Default::default() },
            None,
        ),
        Workload::repeat(eval.clone(), manifest.microbatch, 10),
    )
    .unwrap();
    let target = ceiling.throughput * 0.7;
    let mb_per_sec = ceiling.throughput / manifest.microbatch as f64;
    // Link that can move only 1/6 of fp32 volume at the offered microbatch rate.
    let tight = act_bits * mb_per_sec / 6.0;
    let switch_t = 25.0 / mb_per_sec; // ~25 microbatches of tight phase
    let mut traces = vec![BandwidthTrace::unlimited(); n_links];
    traces[0] = BandwidthTrace::from_points(&[(0.0, tight), (switch_t, f64::INFINITY)]);

    let spec = hlo_spec(
        &manifest, &dir, &cfg,
        traces,
        LinkQuant { method: Method::Pda, initial_bits: 32, ..Default::default() },
        Some(AdaptConfig {
            target_rate: target,
            microbatch: manifest.microbatch,
            policy: Policy::Ladder,
            raise_margin: 1.0,
        }),
    );
    let report = run(spec, Workload::repeat(eval, manifest.microbatch, 60)).unwrap();
    let seq = report.timeline.bits_sequence(0);
    assert!(seq.iter().any(|&b| b < 32), "controller never compressed: {seq:?}");
    assert_eq!(
        report.timeline.final_bits(0),
        Some(32),
        "controller should return to 32-bit after recovery: {seq:?}"
    );
}

#[test]
fn hlo_codec_backend_runs_pipeline() {
    let Some((manifest, dir, eval, mut cfg)) = setup() else { return };
    cfg.pipeline.codec_backend = "hlo".into();
    let spec = hlo_spec(
        &manifest, &dir, &cfg,
        vec![BandwidthTrace::constant(mbps(500.0)); manifest.stages.len() - 1],
        LinkQuant { method: Method::Aciq, initial_bits: 8, ..Default::default() },
        None,
    );
    let report = run(spec, Workload::repeat(eval, manifest.microbatch, 6)).unwrap();
    assert_eq!(report.microbatches, 6);
    assert!(
        report.accuracy > manifest.model.fp32_top1 - 0.05,
        "hlo-codec accuracy: {}",
        report.accuracy
    );
}

#[test]
fn lossy_link_still_completes() {
    let Some((manifest, dir, eval, mut cfg)) = setup() else { return };
    cfg.net.loss_p = 0.05;
    cfg.net.jitter_ms = 0.2;
    let spec = hlo_spec(
        &manifest, &dir, &cfg,
        vec![BandwidthTrace::constant(mbps(300.0)); manifest.stages.len() - 1],
        LinkQuant { method: Method::Pda, initial_bits: 8, ..Default::default() },
        None,
    );
    let report = run(spec, Workload::repeat(eval, manifest.microbatch, 8)).unwrap();
    assert_eq!(report.microbatches, 8);
}
