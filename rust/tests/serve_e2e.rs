//! Multi-stream serving plane, end to end over REAL localhost TCP:
//!
//! * the acceptance run — three concurrent client streams interleaved
//!   through one 3-stage worker chain, completing with zero loss or
//!   duplication, per-stream FIFO order, and per-stream latency
//!   percentiles in the merged `PipelineReport` JSON;
//! * the fairness battery — one greedy client offering 10x the load of
//!   two light clients over a striped resilient boundary running the
//!   `flash_crowd` scenario: the greedy stream (and only the greedy
//!   stream) must absorb the backpressure, and the light streams' p99
//!   completion latency must stay bounded instead of being starved
//!   behind the greedy backlog.
//!
//! Seeded like the chaos soak: a failing fairness run replays with
//! `QUANTPIPE_CHAOS_SEED=<seed> cargo test --test serve_e2e`.

use quantpipe::data::EvalSet;
use quantpipe::net::resilient::ResilienceConfig;
use quantpipe::net::scenario::ScenarioKind;
use quantpipe::net::stripe::striped_loopback_pair;
use quantpipe::net::tcp;
use quantpipe::pipeline::{
    mock_stage_factory, run_serving_coordinator, run_worker, LinkQuant, ServeConfig,
    ServeWorkload, StreamSpec, WorkerConfig,
};
use quantpipe::quant::Method;
use std::sync::Arc;
use std::time::Duration;

fn eval(count: usize, classes: usize) -> Arc<EvalSet> {
    Arc::new(EvalSet::synthetic_onehot(count, classes))
}

/// One direction of a loopback socket pair (the unused halves drop).
fn pipe() -> (tcp::TcpFrameSender, tcp::TcpFrameReceiver) {
    let ((tx, _a_rx), (_b_tx, rx)) = tcp::loopback_pair().unwrap();
    (tx, rx)
}

fn fast_resilience() -> ResilienceConfig {
    ResilienceConfig {
        replay_capacity: 32,
        reconnect_timeout: Duration::from_secs(5),
        initial_timeout: Duration::from_secs(5),
        backoff_base: Duration::from_millis(2),
        backoff_max: Duration::from_millis(20),
        jitter: 0.5,
        hello_timeout: Duration::from_millis(500),
        drain_timeout: Duration::from_secs(5),
        seed: 7,
    }
}

/// Rotating-seed hook shared with the nightly chaos job.
fn chaos_seed() -> u64 {
    std::env::var("QUANTPIPE_CHAOS_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(7)
}

fn worker_cfg(stage: usize, last: bool, s: usize) -> WorkerConfig {
    WorkerConfig {
        stage,
        quant: LinkQuant { method: Method::Aciq, initial_bits: 8, ..Default::default() },
        adapt: None,
        window: 4,
        microbatch: s,
        quantize_output: !last,
        inflight: 2,
        telemetry: true,
    }
}

#[test]
fn three_streams_through_three_stages_end_to_end() {
    // The acceptance run: 3 concurrent client streams (weights 4/2/1)
    // through a coordinator → w0 → w1 → w2 → coordinator chain over
    // plain TCP sockets. Every stream's microbatches must complete with
    // zero loss or duplication and in per-stream FIFO order — the sink
    // converts any demux or FIFO violation into a report error, so a
    // clean error list IS the ordering assertion.
    let classes = 16;
    let s = 8usize;
    let per_stream = 8u64;
    let weights = [4u32, 2, 1];
    let total = per_stream * weights.len() as u64;
    let (c2w0_tx, c2w0_rx) = pipe();
    let (w01_tx, w01_rx) = pipe();
    let (w12_tx, w12_rx) = pipe();
    let (w2c_tx, w2c_rx) = pipe();

    let (cfg0, cfg1, cfg2) =
        (worker_cfg(0, false, s), worker_cfg(1, false, s), worker_cfg(2, true, s));
    let w0 = std::thread::spawn(move || {
        run_worker(
            mock_stage_factory(1.0, 0.0, vec![s, classes], Duration::ZERO),
            cfg0,
            Box::new(c2w0_rx),
            Box::new(w01_tx),
        )
    });
    let w1 = std::thread::spawn(move || {
        run_worker(
            mock_stage_factory(1.0, 0.0, vec![s, classes], Duration::ZERO),
            cfg1,
            Box::new(w01_rx),
            Box::new(w12_tx),
        )
    });
    let w2 = std::thread::spawn(move || {
        run_worker(
            mock_stage_factory(1.0, 0.0, vec![s, classes], Duration::ZERO),
            cfg2,
            Box::new(w12_rx),
            Box::new(w2c_tx),
        )
    });

    let workload = ServeWorkload {
        eval: eval(64, classes),
        microbatch: s,
        streams: weights
            .iter()
            .map(|&weight| StreamSpec { weight, microbatches: per_stream })
            .collect(),
        serve: ServeConfig { max_streams: 3, queue_depth: 4 },
    };
    let report =
        run_serving_coordinator(workload, Box::new(c2w0_tx), Box::new(w2c_rx)).unwrap();

    // (1) Zero loss, zero duplication, per-stream FIFO (violations would
    // land in `errors`), payload intact end to end.
    assert_eq!(report.microbatches, total, "{report:?}");
    assert_eq!(report.images, total * s as u64);
    assert!(report.errors.is_empty(), "FIFO/demux/transport violations: {:?}", report.errors);
    assert!((report.accuracy - 1.0).abs() < 1e-12, "payload corrupted: {report:?}");
    assert_eq!(report.latency.count(), total);

    for (i, w) in vec![w0, w1, w2].into_iter().enumerate() {
        let r = w.join().unwrap().unwrap();
        assert_eq!(r.frames, total, "worker {i} must see every stream's frames");
        assert!(r.errors.is_empty(), "worker {i}: {:?}", r.errors);
    }

    // (2) Worker telemetry is unchanged by multi-streaming: one merged
    // view with every stage's full frame count (stages are
    // stream-oblivious; the stream tag is coordinator-side routing).
    let p = &report.pipeline;
    assert_eq!(p.stage_count(), 3, "every stage must report: {p:?}");
    for stage in 0..3u32 {
        let st = &p.stages[&stage];
        assert_eq!(st.frames, total, "stage {stage} frame count");
        assert!(st.complete, "stage {stage} final snapshot must arrive");
    }

    // (3) The per-stream rows: one per client, full frame counts, the
    // clamped weights, and populated completion percentiles.
    let c = p.coordinator.as_ref().expect("serving run must publish a coordinator summary");
    assert_eq!(c.streams.len(), 3, "{c:?}");
    for (i, row) in c.streams.iter().enumerate() {
        assert_eq!(row.stream, i as u32);
        assert_eq!(row.weight, weights[i], "weights within MAX_WEIGHT pass through");
        assert_eq!(row.frames, per_stream, "stream {i} must complete its whole session");
        assert!(row.p99_latency_s > 0.0, "stream {i} percentiles unpopulated: {row:?}");
        assert!(
            row.p50_latency_s <= row.p99_latency_s,
            "stream {i} percentile order: {row:?}"
        );
    }

    // (4) The merged report serializes with the per-stream rows, parses
    // back, and renders them.
    let json = p.to_json().to_string_pretty();
    let back = quantpipe::metrics::telemetry::PipelineReport::from_json(
        &quantpipe::util::json::Value::parse(&json).unwrap(),
    )
    .unwrap();
    let bc = back.coordinator.as_ref().unwrap();
    assert_eq!(bc.streams.len(), 3, "per-stream rows lost in JSON: {json}");
    for (a, b) in c.streams.iter().zip(&bc.streams) {
        assert_eq!((a.stream, a.weight, a.frames, a.stalls), (b.stream, b.weight, b.frames, b.stalls));
        assert!((a.p99_latency_s - b.p99_latency_s).abs() < 1e-9, "{a:?} vs {b:?}");
    }
    let text = back.render();
    assert!(text.contains("stream 0") && text.contains("stream 2"), "{text}");
}

#[test]
fn fairness_greedy_stream_absorbs_the_backpressure() {
    // The starvation battery: one greedy client offers 10x the load of
    // two light clients, the first boundary is striped (2 stripes) and
    // resilient, and the whole boundary rides the `flash_crowd` scenario
    // (bandwidth surge to 12 Mbps, 6 ms jitter, light loss). The bounded
    // per-stream queues + WRR dispatch must hold the GREEDY client at
    // admission while the light clients' microbatches keep flowing: the
    // greedy row absorbs the stalls, and the light rows' p99 completion
    // latency stays far below the greedy row's (which funds the whole
    // backlog it created).
    let seed = chaos_seed();
    eprintln!("fairness seed {seed} (replay: QUANTPIPE_CHAOS_SEED={seed})");
    let classes = 16;
    let s = 8usize;
    let greedy = 50u64;
    let light = 5u64;
    let total = greedy + 2 * light;
    let stripes = 2usize;

    let (mut c2w0_tx, c2w0_rx) = striped_loopback_pair(stripes, &fast_resilience()).unwrap();
    for (i, sh) in ScenarioKind::FlashCrowd.build(seed, stripes).into_iter().enumerate() {
        c2w0_tx.set_shaper(i, sh);
    }
    let (w01_tx, w01_rx) = pipe();
    let (w12_tx, w12_rx) = pipe();
    let (w2c_tx, w2c_rx) = pipe();

    let (cfg0, cfg1, cfg2) =
        (worker_cfg(0, false, s), worker_cfg(1, false, s), worker_cfg(2, true, s));
    let w0 = std::thread::spawn(move || {
        run_worker(
            mock_stage_factory(1.0, 0.0, vec![s, classes], Duration::ZERO),
            cfg0,
            Box::new(c2w0_rx),
            Box::new(w01_tx),
        )
    });
    let w1 = std::thread::spawn(move || {
        // 2 ms of compute paces the chain so the greedy backlog builds.
        run_worker(
            mock_stage_factory(1.0, 0.0, vec![s, classes], Duration::from_millis(2)),
            cfg1,
            Box::new(w01_rx),
            Box::new(w12_tx),
        )
    });
    let w2 = std::thread::spawn(move || {
        run_worker(
            mock_stage_factory(1.0, 0.0, vec![s, classes], Duration::ZERO),
            cfg2,
            Box::new(w12_rx),
            Box::new(w2c_tx),
        )
    });

    let workload = ServeWorkload {
        eval: eval(64, classes),
        microbatch: s,
        streams: vec![
            StreamSpec { weight: 1, microbatches: greedy },
            StreamSpec { weight: 1, microbatches: light },
            StreamSpec { weight: 1, microbatches: light },
        ],
        // Shallow queues: the greedy client hits its depth almost
        // immediately and blocks at admission for the rest of the run.
        serve: ServeConfig { max_streams: 3, queue_depth: 2 },
    };
    let report =
        run_serving_coordinator(workload, Box::new(c2w0_tx), Box::new(w2c_rx)).unwrap();

    // Chaos must not cost correctness: every stream completes exactly
    // once, in order, payloads intact (losses ride the replay path).
    assert_eq!(report.microbatches, total, "{report:?}");
    assert!(report.errors.is_empty(), "chaos surfaced as a hard error: {:?}", report.errors);
    assert!((report.accuracy - 1.0).abs() < 1e-12, "payload corrupted: {report:?}");
    for (i, w) in vec![w0, w1, w2].into_iter().enumerate() {
        let r = w.join().unwrap().unwrap();
        assert_eq!(r.frames, total, "worker {i}");
        assert!(r.errors.is_empty(), "worker {i}: {:?}", r.errors);
    }

    let c = report.pipeline.coordinator.as_ref().expect("coordinator summary");
    assert_eq!(c.streams.len(), 3, "{c:?}");
    let g = &c.streams[0];
    assert_eq!(g.frames, greedy, "greedy stream must still complete: {g:?}");
    // (1) The greedy stream is the one backpressured: its 10x offered
    // load against a depth-2 queue must stall at admission…
    assert!(g.stalls >= 1, "greedy client never hit backpressure (seed {seed}): {g:?}");
    for row in &c.streams[1..] {
        let id = row.stream;
        assert_eq!(row.frames, light, "light stream {id} starved of completions: {row:?}");
        // …and it must absorb at least as many stalls as either light
        // client — the "who was held back" counter points at the hog.
        assert!(
            g.stalls >= row.stalls,
            "light stream {id} absorbed more backpressure than the greedy one \
             (seed {seed}): greedy {g:?} vs {row:?}"
        );
        // (2) No starvation: a light client's whole 5-microbatch session
        // clears while the greedy backlog is still being worked off, so
        // its p99 completion latency sits far below the greedy stream's
        // (which funds its own queueing delay) and under an absolute
        // ceiling that a starved stream (parked behind ~50 greedy
        // microbatches of surge traffic) would blow through.
        assert!(
            row.p99_latency_s <= g.p99_latency_s,
            "light stream {id} waited behind the greedy backlog (seed {seed}): \
             light p99 {} vs greedy p99 {}",
            row.p99_latency_s,
            g.p99_latency_s
        );
        assert!(
            row.p99_latency_s < 2.0,
            "light stream {id} p99 {}s blows the starvation bound (seed {seed}): {c:?}",
            row.p99_latency_s
        );
    }
}
