//! The self-hosted static-analysis pass over this crate's own sources.
//!
//! Runs as part of `cargo test -q`, so CI enforces the codebase's
//! structural invariants (see `src/analysis/`) with zero extra tooling:
//!
//! * no bare `.unwrap()`/`.expect(` in non-test net/pipeline code;
//! * all mutex acquisition through `util::sync` (the lock-order
//!   detector's coverage guarantee);
//! * `net/session.rs` stays socket-free;
//! * every `unsafe` carries a `// SAFETY:` comment;
//! * wire-protocol constants match `docs/WIRE_PROTOCOL.md`.

use quantpipe::analysis::{crate_sources, lints, spec};
use std::path::Path;

fn sources() -> Vec<quantpipe::analysis::SourceFile> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR"));
    crate_sources(dir).expect("walking the crate's own sources")
}

#[test]
fn repo_is_lint_clean() {
    let findings = lints::run_all(&sources());
    if !findings.is_empty() {
        let mut msg = format!("{} lint finding(s):\n", findings.len());
        for f in &findings {
            msg.push_str(&format!("  {f}\n"));
        }
        msg.push_str(
            "fix the code, or annotate with `// lint: allow(<rule>): <reason>` \
             where the invariant provably holds",
        );
        panic!("{msg}");
    }
}

#[test]
fn lint_pass_actually_sees_the_tree() {
    // Guards against the walker silently finding nothing (e.g. after a
    // directory move): the pass must cover the core protocol files.
    let files = sources();
    for expect in ["src/net/session.rs", "src/pipeline/driver.rs", "src/util/sync.rs"] {
        assert!(
            files.iter().any(|f| f.rel() == expect),
            "lint walker lost {expect}; coverage would be vacuous"
        );
    }
    // And the tree must contain the annotations the rules credit —
    // if someone strips them wholesale the lint should have fired.
    let total_lines: usize = files.iter().map(|f| f.lines.len()).sum();
    assert!(total_lines > 1000, "implausibly small tree: {total_lines} lines");
}

#[test]
fn wire_constants_match_the_normative_doc() {
    let doc_path = Path::new(env!("CARGO_MANIFEST_DIR")).join("../docs/WIRE_PROTOCOL.md");
    let doc = std::fs::read_to_string(&doc_path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", doc_path.display()));
    let parsed = spec::parse(&doc).expect("normative tables must stay parseable");
    let diffs = spec::cross_check(&parsed);
    if !diffs.is_empty() {
        let mut msg = format!("{} wire-spec mismatch(es):\n", diffs.len());
        for d in &diffs {
            msg.push_str(&format!("  {d}\n"));
        }
        msg.push_str("docs/WIRE_PROTOCOL.md and net::{session,frame} must agree");
        panic!("{msg}");
    }
}
