//! The self-hosted static-analysis pass over this crate's own sources.
//!
//! Runs as part of `cargo test -q`, so CI enforces the codebase's
//! structural invariants (see `src/analysis/`) with zero extra tooling:
//!
//! * no bare `.unwrap()`/`.expect(` in non-test net/pipeline code;
//! * all mutex acquisition through `util::sync` (the lock-order
//!   detector's coverage guarantee);
//! * `net/session.rs` stays socket-free;
//! * every `unsafe` carries a `// SAFETY:` comment;
//! * wire-protocol constants match `docs/WIRE_PROTOCOL.md`.

use quantpipe::analysis::{crate_sources, lints, spec};
use std::path::Path;

fn sources() -> Vec<quantpipe::analysis::SourceFile> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR"));
    crate_sources(dir).expect("walking the crate's own sources")
}

#[test]
fn repo_is_lint_clean() {
    let findings = lints::run_all(&sources());
    if !findings.is_empty() {
        let mut msg = format!("{} lint finding(s):\n", findings.len());
        for f in &findings {
            msg.push_str(&format!("  {f}\n"));
        }
        msg.push_str(
            "fix the code, or annotate with `// lint: allow(<rule>): <reason>` \
             where the invariant provably holds",
        );
        panic!("{msg}");
    }
}

#[test]
fn lint_pass_actually_sees_the_tree() {
    // Guards against the walker silently finding nothing (e.g. after a
    // directory move): the pass must cover the core protocol files.
    let files = sources();
    for expect in ["src/net/session.rs", "src/pipeline/driver.rs", "src/util/sync.rs"] {
        assert!(
            files.iter().any(|f| f.rel() == expect),
            "lint walker lost {expect}; coverage would be vacuous"
        );
    }
    // And the tree must contain the annotations the rules credit —
    // if someone strips them wholesale the lint should have fired.
    let total_lines: usize = files.iter().map(|f| f.lines.len()).sum();
    assert!(total_lines > 1000, "implausibly small tree: {total_lines} lines");
}

#[test]
fn safety_lint_catches_a_seeded_violation_in_the_simd_kernels() {
    // End-to-end negative test for R4 against the real SIMD source: strip
    // every SAFETY: annotation from `quant/fused.rs` (the crate's densest
    // unsafe code) and the lint must light up; the pristine file must be
    // clean. Guards against the rule silently rotting into a no-op while
    // `repo_is_lint_clean` keeps passing vacuously.
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("src/quant/fused.rs");
    let text = std::fs::read_to_string(&path).expect("reading quant/fused.rs");
    assert!(text.contains("unsafe"), "fused.rs lost its SIMD kernels?");
    assert!(text.contains("SAFETY:"), "fused.rs lost its SAFETY comments?");

    let clean = quantpipe::analysis::SourceFile::parse("src/quant/fused.rs", &text, false);
    let mut findings = Vec::new();
    lints::check_safety_comments(&clean, &mut findings);
    assert!(findings.is_empty(), "pristine fused.rs must be R4-clean: {findings:?}");

    let doctored = text.replace("SAFETY:", "SAFETY_REMOVED");
    let seeded = quantpipe::analysis::SourceFile::parse("src/quant/fused.rs", &doctored, false);
    let mut findings = Vec::new();
    lints::check_safety_comments(&seeded, &mut findings);
    assert!(!findings.is_empty(), "stripping SAFETY: comments must trip R4");
    assert!(findings.iter().all(|f| f.rule == "safety-comment"), "{findings:?}");
}

#[test]
fn wire_constants_match_the_normative_doc() {
    let doc_path = Path::new(env!("CARGO_MANIFEST_DIR")).join("../docs/WIRE_PROTOCOL.md");
    let doc = std::fs::read_to_string(&doc_path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", doc_path.display()));
    let parsed = spec::parse(&doc).expect("normative tables must stay parseable");
    let diffs = spec::cross_check(&parsed);
    if !diffs.is_empty() {
        let mut msg = format!("{} wire-spec mismatch(es):\n", diffs.len());
        for d in &diffs {
            msg.push_str(&format!("  {d}\n"));
        }
        msg.push_str("docs/WIRE_PROTOCOL.md and net::{session,frame} must agree");
        panic!("{msg}");
    }
}
