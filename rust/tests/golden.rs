//! Cross-language golden tests: the rust quantization library must
//! reproduce the numbers of the python oracle (kernels/ref.py) recorded in
//! artifacts/golden.json by `make artifacts`.
//!
//! Run after `make artifacts` (the Makefile's `test` target does).
//! Without the artifacts these tests SKIP with a notice; set
//! `QUANTPIPE_REQUIRE_ARTIFACTS=1` to make a missing golden.json fail.

use quantpipe::quant::{aciq, calibrate, ds_aciq, uniform, Method, QuantParams};
use quantpipe::runtime::Manifest;
use quantpipe::util::json::Value;

fn load_golden() -> Option<Value> {
    let dir = Manifest::default_dir();
    let text = match std::fs::read_to_string(dir.join("golden.json")) {
        Ok(t) => t,
        Err(e) if std::env::var_os("QUANTPIPE_REQUIRE_ARTIFACTS").is_some() => {
            panic!("artifacts/golden.json required but unavailable: {e}")
        }
        Err(e) => {
            eprintln!("SKIP (artifacts/golden.json missing — run `make artifacts`): {e}");
            return None;
        }
    };
    Some(Value::parse(&text).expect("golden.json parses"))
}

fn f32s(v: &Value) -> Vec<f32> {
    v.as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap() as f32)
        .collect()
}

/// Reconstruct each named sample distribution exactly as aot.py did —
/// from the *same* recorded inputs. golden.json only records derived
/// values per (sample, bits); the sample data itself comes from
/// artifacts/calib.bin (boundary slice) or is re-deriveable. To keep the
/// test self-contained we use the recorded scalar statistics instead:
/// b_e is checked against laplace_b on the recorded exact vector, and the
/// per-case b_e/alpha/ds values are verified for internal consistency
/// (ratio * b_e == alpha) plus against the rust implementations on the
/// boundary slice reconstructed from calib.bin.
#[test]
fn aciq_ratio_matches_python() {
    let Some(g) = load_golden() else { return };
    for case in g.at("cases").unwrap().as_arr().unwrap() {
        let q = case.at("q").unwrap().as_u64().unwrap() as u8;
        let py_ratio = case.at("aciq_ratio").unwrap().as_f64().unwrap();
        let rust_ratio = aciq::ratio(q) as f64;
        assert!(
            (py_ratio - rust_ratio).abs() < 1e-4,
            "F({q}): py {py_ratio} vs rust {rust_ratio}"
        );
        // alpha = ratio * b_e consistency
        let b_e = case.at("b_e").unwrap().as_f64().unwrap();
        let alpha = case.at("aciq_alpha").unwrap().as_f64().unwrap();
        assert!((alpha - py_ratio * b_e).abs() / alpha.max(1e-9) < 1e-5);
    }
}

#[test]
fn boundary_slice_statistics_match() {
    let Some(g) = load_golden() else { return };
    let dir = Manifest::default_dir();
    let Ok((manifest, dir)) = Manifest::load(&dir) else {
        eprintln!("SKIP (artifacts manifest missing)");
        return;
    };
    let calib = quantpipe::data::load_calib(dir.join(&manifest.calib.file)).unwrap();
    let slice: Vec<f32> = calib[0].data.iter().take(4096).copied().collect();

    for case in g.at("cases").unwrap().as_arr().unwrap() {
        if case.at("name").unwrap().as_str().unwrap() != "boundary0_slice" {
            continue;
        }
        let q = case.at("q").unwrap().as_u64().unwrap() as u8;
        let py_b_e = case.at("b_e").unwrap().as_f64().unwrap();
        let rust_b_e = aciq::laplace_b(&slice) as f64;
        assert!(
            (py_b_e - rust_b_e).abs() / py_b_e < 1e-4,
            "b_e mismatch: py {py_b_e} rust {rust_b_e}"
        );

        // Naive params
        let p = uniform::naive_params(&slice, q);
        let py_scale = case.at("naive_scale").unwrap().as_f64().unwrap();
        assert!(
            ((p.scale as f64) - py_scale).abs() / py_scale < 1e-4,
            "naive scale q={q}"
        );
        let py_zp = case.at("naive_zp").unwrap().as_f64().unwrap();
        assert!(((p.zero_point as f64) - py_zp).abs() <= 1.0, "naive zp q={q}");

        // Quantization MSEs
        let py_mse = case.at("naive_mse").unwrap().as_f64().unwrap();
        let rust_mse = uniform::quant_mse(&slice, &p);
        assert!(
            (py_mse - rust_mse).abs() / py_mse.max(1e-12) < 5e-3,
            "naive mse q={q}: py {py_mse} rust {rust_mse}"
        );
        let py_aciq_mse = case.at("aciq_mse").unwrap().as_f64().unwrap();
        let rust_aciq_mse = uniform::quant_mse(&slice, &calibrate(&slice, Method::Aciq, q));
        assert!(
            (py_aciq_mse - rust_aciq_mse).abs() / py_aciq_mse.max(1e-12) < 5e-3,
            "aciq mse q={q}: py {py_aciq_mse} rust {rust_aciq_mse}"
        );

        // DS-ACIQ refined scale
        let py_b_star = case.at("ds_b_star").unwrap().as_f64().unwrap();
        let r = ds_aciq::ds_aciq_b(&slice, q, ds_aciq::DEFAULT_STEPS);
        assert!(
            (py_b_star - r.b_star as f64).abs() / py_b_star < 5e-3,
            "ds b* q={q}: py {py_b_star} rust {}",
            r.b_star
        );
    }
}

#[test]
fn exact_code_vectors_match() {
    let Some(g) = load_golden() else { return };
    let x = f32s(g.at("x_small").unwrap());
    for case in g.at("exact").unwrap().as_arr().unwrap() {
        let q = case.at("q").unwrap().as_u64().unwrap() as u8;
        let p = QuantParams {
            scale: case.at("scale").unwrap().as_f64().unwrap() as f32,
            zero_point: case.at("zp").unwrap().as_f64().unwrap() as f32,
            lo: case.at("lo").unwrap().as_f64().unwrap() as f32,
            hi: case.at("hi").unwrap().as_f64().unwrap() as f32,
            bits: q,
        };
        let want: Vec<i32> = case
            .at("codes")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as i32)
            .collect();
        let got = uniform::quantize(&x, &p);
        // Allow ±1 code on exact rounding ties only.
        for (i, (w, g_)) in want.iter().zip(&got).enumerate() {
            assert!(
                (w - g_).abs() <= 1,
                "mode {} q={q} elem {i}: py {w} rust {g_}",
                case.at("mode").unwrap().as_str().unwrap()
            );
        }
        let ties = want.iter().zip(&got).filter(|(w, g_)| w != g_).count();
        assert!(ties <= 1, "too many code mismatches: {ties}");
    }
}
