//! Chaos transport soak: the full adaptive pipeline under a seeded
//! composite impairment scenario, over REAL localhost TCP sockets.
//!
//! The `composite_chaos` scenario exercises every fault axis at once —
//! per-stripe bandwidth fades (trace-driven token bucket), delay+jitter,
//! byte corruption on stripe 0, frame loss on stripe 1 and a partition
//! window on the last stripe — and the run must still deliver every
//! microbatch exactly once, in order, shed bits while the fade bites,
//! attribute reconnects to the impaired stripes, and drain cleanly.
//!
//! Every impairment decision is deterministic from one seed, printed at
//! the start of the soak: a failing run replays with
//! `QUANTPIPE_CHAOS_SEED=<seed> cargo test --test chaos_soak`.

use quantpipe::adapt::{AdaptConfig, Policy};
use quantpipe::data::EvalSet;
use quantpipe::net::frame::Frame;
use quantpipe::net::resilient::ResilienceConfig;
use quantpipe::net::scenario::ScenarioKind;
use quantpipe::net::shaper::{HotTouchScope, LinkShaper, ShaperSpec};
use quantpipe::net::stripe::striped_loopback_pair;
use quantpipe::net::transport::LinkSpec;
use quantpipe::pipeline::{mock_stage_factory, run, LinkQuant, PipelineSpec, Workload};
use quantpipe::quant::Method;
use std::sync::Arc;
use std::time::Duration;

/// Rotating-seed hook for the nightly chaos job; defaults to a pinned
/// seed for regular runs.
fn chaos_seed() -> u64 {
    std::env::var("QUANTPIPE_CHAOS_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(7)
}

fn fast_resilience() -> ResilienceConfig {
    ResilienceConfig {
        replay_capacity: 32,
        reconnect_timeout: Duration::from_secs(5),
        initial_timeout: Duration::from_secs(5),
        backoff_base: Duration::from_millis(2),
        backoff_max: Duration::from_millis(20),
        jitter: 0.5,
        hello_timeout: Duration::from_millis(500),
        drain_timeout: Duration::from_secs(5),
        seed: 7,
    }
}

fn eval(count: usize, classes: usize) -> Arc<EvalSet> {
    Arc::new(EvalSet::synthetic_onehot(count, classes))
}

#[test]
fn unshaped_boundary_runs_zero_shaper_code() {
    // The zero-cost-when-disabled regression: a transfer over a striped
    // boundary with no shaper attached must not execute a single shaper
    // decision — asserted on the global hot-touch counter instead of a
    // flaky wall-clock comparison. This is the `scenario: none`
    // guarantee: the write path is byte-identical to the pre-chaos-lab
    // build. The HotTouchScope holds the observer gate for the window,
    // so the shaped tests in this binary run in PARALLEL with this one:
    // their decisions park at the gate for the scope's (short) lifetime
    // instead of polluting the delta.
    let scope = HotTouchScope::begin();
    let (mut tx, mut rx) = striped_loopback_pair(2, &fast_resilience()).unwrap();
    let total = 8u64;
    let sender = std::thread::spawn(move || {
        let mut c = quantpipe::quant::codec::Codec::default();
        for seq in 0..total {
            let x: Vec<f32> = (0..64).map(|i| (i as f32 + seq as f32).sin()).collect();
            let enc = c.encode(&x, Method::Aciq, 8).unwrap();
            tx.send(Frame::new(seq, vec![64], enc)).unwrap();
        }
        tx.finish().unwrap();
    });
    for want in 0..total {
        assert_eq!(rx.recv().unwrap().unwrap().seq, want);
    }
    assert!(rx.recv().unwrap().is_none());
    sender.join().unwrap();
    assert_eq!(
        scope.delta(),
        0,
        "an unshaped transfer executed shaper code on the write path"
    );
}

#[test]
fn certain_corruption_still_delivers_exactly_once() {
    // Satellite of the tcp.rs corrupt-frame hard error: on a SESSION
    // link, corruption is survivable. With corrupt_p = 1.0 every fresh
    // write puts a flipped byte on the wire; the receiver's CRC check
    // rejects the frame and drops the conduit as desynced; the reconnect
    // handshake replays the pristine bytes from the replay buffer. So
    // the stream makes progress purely through the replay path — and
    // must still arrive exactly once, in order, with a clean FIN drain.
    // (No gate needed: the assertions ride this test's own per-shaper
    // and per-link counters, which no parallel test can touch.)
    let (mut tx, mut rx) = striped_loopback_pair(1, &fast_resilience()).unwrap();
    let stats = tx.stats();
    let shaper = Arc::new(LinkShaper::new(ShaperSpec {
        corrupt_p: 1.0,
        seed: chaos_seed(),
        ..ShaperSpec::default()
    }));
    tx.set_shaper(0, Some(shaper.clone()));
    let total = 8u64;
    let sender = std::thread::spawn(move || {
        let mut c = quantpipe::quant::codec::Codec::default();
        for seq in 0..total {
            let x: Vec<f32> = (0..64).map(|i| (i as f32 + seq as f32).sin()).collect();
            let enc = c.encode(&x, Method::Aciq, 8).unwrap();
            tx.send(Frame::new(seq, vec![64], enc)).unwrap();
        }
        tx.finish().unwrap();
    });
    for want in 0..total {
        assert_eq!(
            rx.recv().unwrap().unwrap().seq,
            want,
            "loss/dup/reorder under certain corruption"
        );
    }
    assert!(rx.recv().unwrap().is_none(), "FIN must still close the boundary cleanly");
    sender.join().unwrap();
    let sh = shaper.stats();
    assert!(sh.corrupted >= 1, "the shaper never corrupted a write: {sh:?}");
    assert!(
        stats.snapshot().reconnects >= 1,
        "corruption must surface as conduit desync + reconnect: {:?}",
        stats.snapshot()
    );
}

#[test]
fn chaos_soak_composite_scenario_end_to_end() {
    // The capstone: a 3-stage adaptive pipeline whose first boundary is
    // striped over 3 connections carrying the full `composite_chaos`
    // schedule — fade traces on every stripe, corruption on stripe 0,
    // loss on stripe 1, a partition window on stripe 2 — while stage 1
    // paces the pipeline so the run is still in flight when the fade
    // trough arrives. Runs in parallel with its siblings: everything it
    // asserts is per-shaper or per-link, never process-global.
    let seed = chaos_seed();
    eprintln!("chaos soak seed {seed} (replay: QUANTPIPE_CHAOS_SEED={seed})");

    let classes = 256; // 8x256 f32 ≈ 8 KB per raw frame
    let s = 8usize;
    let total = 120u64;
    let stripes = 3usize;
    let scenario = ScenarioKind::CompositeChaos;
    for line in scenario.timeline(seed, stripes) {
        eprintln!("  {line}");
    }
    let shapers = scenario.build(seed, stripes);
    let mut link0 = LinkSpec::tcp_loopback_striped(stripes, fast_resilience()).unwrap();
    assert!(link0.set_stripe_shapers(shapers.clone()), "striped link must accept shapers");
    let link1 = LinkSpec::tcp_loopback_resilient(fast_resilience()).unwrap();
    let per_stripe = link0.stripe_stats().unwrap();
    let stats0 = link0.resilience().unwrap();

    let spec = PipelineSpec {
        stages: vec![
            mock_stage_factory(1.0, 0.0, vec![s, classes], Duration::ZERO),
            // 15 ms per microbatch: the run lasts ≥ 1.8 s, so the fade
            // trough (which starts by t = 1.6 s for every seed) always
            // lands mid-stream.
            mock_stage_factory(1.0, 0.0, vec![s, classes], Duration::from_millis(15)),
            mock_stage_factory(1.0, 0.0, vec![s, classes], Duration::ZERO),
        ],
        links: vec![link0, link1],
        quant: LinkQuant { method: Method::Aciq, initial_bits: 32, ..Default::default() },
        adapt: Some(AdaptConfig {
            // 20 ms budget per microbatch: met in the healthy phases
            // (15 ms compute + ~3 ms serialization at 24 Mbps), broken in
            // the trough (6–10 Mbps puts an 8 KB fp32 frame at 6–11 ms on
            // the wire) — the fade must force bits down.
            target_rate: 400.0,
            microbatch: s,
            policy: Policy::Ladder,
            raise_margin: 1.1,
        }),
        window: 4,
        inflight: 2,
    };
    let report = run(spec, Workload::repeat(eval(64, classes), s, total)).unwrap();

    // (1) Exactly once, in order, end to end: every microbatch delivered
    // and scored, none lost, duplicated or reordered by the chaos.
    assert_eq!(report.microbatches, total, "{report:?}");
    assert_eq!(report.images, total * s as u64);
    assert!(
        report.errors.is_empty(),
        "chaos must never surface as a hard error: {:?}",
        report.errors
    );
    assert!((report.accuracy - 1.0).abs() < 1e-12, "payload corrupted end to end: {report:?}");
    assert_eq!(report.latency.count(), total);

    // (2) The chaos actually bit: the shapers decided every fresh write
    // on the striped boundary, and at least one write was corrupted
    // (stripe 0 corrupts at p = 0.25; ~40 fresh sends land there).
    let decided: u64 = shapers.iter().flatten().map(|sh| sh.stats().frames).sum();
    assert!(decided >= total, "shapers saw too few writes: {decided} < {total}");
    let corrupted: u64 = shapers.iter().flatten().map(|sh| sh.stats().corrupted).sum();
    assert!(corrupted >= 1, "no corruption events in {decided} decisions (seed {seed})");

    // (3) Reconnects exist and are attributed to the impaired stripe:
    // every corrupted write desyncs conduit 0, and the per-stripe
    // counters must show it.
    assert!(
        stats0.snapshot().reconnects >= 1,
        "corruption never surfaced as a reconnect: {:?}",
        stats0.snapshot()
    );
    assert!(
        per_stripe[0].snapshot().reconnects >= 1,
        "reconnects not attributed to the corrupting stripe: {:?}",
        report.stripes
    );

    // (4) Bits shed while the fade bit: the trough breaks the 20 ms
    // budget at fp32, and the controller only sees write stall.
    let seq = report.timeline.bits_sequence(0);
    assert!(
        seq.iter().any(|&b| b < 32),
        "controller never shed bits across the fade (seed {seed}): {seq:?}"
    );

    // (5) Clean drain despite everything: the FIN/FIN_ACK handshake
    // completed on both boundaries (a failed drain reports an error,
    // checked above) and the run report carries the striped boundary's
    // per-stripe wire counters (link 1 is resilient but unstriped).
    assert_eq!(report.stripes.len(), stripes, "per-stripe counters for the striped boundary");
    let carried: u64 = report.stripes.iter().take(stripes).map(|st| st.frames).sum();
    assert!(carried >= total, "the striped boundary must carry every frame: {carried}");
}
