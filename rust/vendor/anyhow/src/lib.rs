//! In-tree, std-only stand-in for the `anyhow` crate.
//!
//! The build environment is fully offline, so the real crates.io `anyhow`
//! cannot be fetched; this shim implements the (small) surface the repo
//! actually uses with compatible semantics:
//!
//! * [`Error`]: an opaque, `Send + Sync` error value holding a message
//!   chain. `Display` prints the outermost message; the alternate form
//!   (`{:#}`) prints the whole chain joined with `": "`; `Debug` prints
//!   the anyhow-style multi-line report with a `Caused by:` section.
//! * [`Result<T>`]: alias for `Result<T, Error>`.
//! * [`anyhow!`], [`bail!`], [`ensure!`]: the formatting macros.
//! * A blanket `From<E: std::error::Error + Send + Sync + 'static>` so `?`
//!   converts concrete error types (the source chain is flattened into the
//!   message chain).
//! * [`Error::context`] and the [`Context`] extension trait for `Result` /
//!   `Option`.
//!
//! Downcasting and backtraces are intentionally out of scope — nothing in
//! the repo uses them, and the whole point of this shim is to keep the
//! tree building with zero external dependencies.

use std::fmt;

/// Opaque error value: a chain of messages, outermost first.
pub struct Error {
    /// `layers[0]` is the outermost (most recently attached) message;
    /// `layers[last]` is the root cause.
    layers: Vec<String>,
}

impl Error {
    /// Construct from a single message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { layers: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (anyhow's `Error::context`).
    #[must_use]
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.layers.insert(0, context.to_string());
        self
    }

    /// The error chain, outermost message first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.layers.iter().map(String::as_str)
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.layers.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the full chain on one line, anyhow-style.
            write!(f, "{}", self.layers.join(": "))
        } else {
            write!(f, "{}", self.layers.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.layers.first().map(String::as_str).unwrap_or(""))?;
        if self.layers.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            if self.layers.len() == 2 {
                write!(f, "\n    {}", self.layers[1])?;
            } else {
                for (i, layer) in self.layers[1..].iter().enumerate() {
                    write!(f, "\n    {i}: {layer}")?;
                }
            }
        }
        Ok(())
    }
}

// Error deliberately does NOT implement std::error::Error — exactly like
// the real anyhow — which is what makes the blanket From below coherent
// (it would otherwise overlap the reflexive `impl From<T> for T`).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut layers = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            layers.push(s.to_string());
            src = s.source();
        }
        Error { layers }
    }
}

/// `Result` specialized to [`Error`], with anyhow's default-param trick so
/// both `anyhow::Result<T>` and `anyhow::Result<T, E>` spell correctly.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait attaching context to `Result` / `Option` (anyhow's
/// `Context`).
pub trait Context<T> {
    /// Wrap the error (or `None`) with a context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Wrap with a lazily-evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any `Display` value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn display_outermost_only() {
        let e = Error::from(io_err()).context("reading manifest");
        assert_eq!(format!("{e}"), "reading manifest");
    }

    #[test]
    fn alternate_joins_chain() {
        let e = Error::from(io_err()).context("reading manifest").context("loading artifacts");
        assert_eq!(format!("{e:#}"), "loading artifacts: reading manifest: disk on fire");
    }

    #[test]
    fn debug_prints_caused_by() {
        let e = Error::from(io_err()).context("reading manifest");
        let dbg = format!("{e:?}");
        assert!(dbg.starts_with("reading manifest"), "{dbg}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
        assert!(dbg.contains("disk on fire"), "{dbg}");
    }

    #[test]
    fn macros_and_question_mark() {
        fn inner(fail: bool) -> Result<u32> {
            ensure!(!fail, "asked to fail with code {}", 7);
            let parsed: u32 = "42".parse()?; // ParseIntError -> Error via blanket From
            if parsed == 0 {
                bail!("zero is not a value");
            }
            Ok(parsed)
        }
        assert_eq!(inner(false).unwrap(), 42);
        assert_eq!(format!("{}", inner(true).unwrap_err()), "asked to fail with code 7");
    }

    #[test]
    fn context_trait_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("spilling").unwrap_err();
        assert_eq!(format!("{e:#}"), "spilling: disk on fire");
        let o: Option<u32> = None;
        assert_eq!(format!("{}", o.context("missing key").unwrap_err()), "missing key");
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_bounds<T: Send + Sync + 'static>() {}
        assert_bounds::<Error>();
    }
}
