//! In-tree stub of the `xla` PJRT FFI crate.
//!
//! The real crate binds `xla_extension` (a native XLA build) and cannot be
//! fetched or linked in the offline build environment. This stub keeps the
//! repo compiling and its non-PJRT paths fully functional:
//!
//! * [`Literal`] is a **real** host-side implementation — `vec1`,
//!   `reshape`, `to_vec` behave faithfully for the `f32`/`i32` dtypes the
//!   repo uses — so code that only marshals tensors works unchanged.
//! * Runtime entry points ([`PjRtClient::cpu`],
//!   [`HloModuleProto::from_text_file`], compile/execute) return
//!   [`Error::Unavailable`]. Callers already treat a missing PJRT plugin
//!   as a skippable condition (see `rust/tests/runtime_hlo.rs`), so tests
//!   and mock-stage pipelines run end to end while HLO execution reports
//!   itself unavailable instead of silently faking results.
//!
//! Swapping the real crate back in is a one-line change in
//! `rust/Cargo.toml`; no call site mentions this stub.

use std::fmt;
use std::path::Path;

/// Stub error: either the runtime is unavailable or a host-side `Literal`
/// operation was misused.
#[derive(Debug, Clone)]
pub enum Error {
    /// The operation needs the native XLA runtime, which this stub lacks.
    Unavailable(&'static str),
    /// A host-side literal operation failed (shape/dtype mismatch).
    Literal(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "{what}: PJRT runtime unavailable (in-tree xla stub; build against the real \
                 `xla` crate for HLO execution)"
            ),
            Error::Literal(msg) => write!(f, "literal error: {msg}"),
        }
    }
}

impl std::error::Error for Error {}

/// Stub result type.
pub type Result<T> = std::result::Result<T, Error>;

// ---------------------------------------------------------------------------
// Literal: real host-side tensor value
// ---------------------------------------------------------------------------

/// Element types [`Literal`] can hold (the repo only uses f32 / i32).
pub trait NativeType: Copy + Sized + private::Sealed {
    /// Wrap a slice as literal storage.
    fn store(data: &[Self]) -> Storage;
    /// Extract a typed copy, `None` on dtype mismatch.
    fn load(storage: &Storage) -> Option<Vec<Self>>;
    /// Dtype name for error messages.
    fn dtype_name() -> &'static str;
}

mod private {
    /// Seals [`super::NativeType`] to the dtypes the repo uses.
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for i32 {}
}

/// Dtype-erased literal storage.
#[derive(Debug, Clone, PartialEq)]
pub enum Storage {
    /// 32-bit float elements.
    F32(Vec<f32>),
    /// 32-bit signed integer elements.
    I32(Vec<i32>),
}

impl Storage {
    fn len(&self) -> usize {
        match self {
            Storage::F32(v) => v.len(),
            Storage::I32(v) => v.len(),
        }
    }

    fn dtype_name(&self) -> &'static str {
        match self {
            Storage::F32(_) => "f32",
            Storage::I32(_) => "i32",
        }
    }
}

impl NativeType for f32 {
    fn store(data: &[Self]) -> Storage {
        Storage::F32(data.to_vec())
    }

    fn load(storage: &Storage) -> Option<Vec<Self>> {
        match storage {
            Storage::F32(v) => Some(v.clone()),
            Storage::I32(_) => None,
        }
    }

    fn dtype_name() -> &'static str {
        "f32"
    }
}

impl NativeType for i32 {
    fn store(data: &[Self]) -> Storage {
        Storage::I32(data.to_vec())
    }

    fn load(storage: &Storage) -> Option<Vec<Self>> {
        match storage {
            Storage::I32(v) => Some(v.clone()),
            Storage::F32(_) => None,
        }
    }

    fn dtype_name() -> &'static str {
        "i32"
    }
}

/// A host tensor value (dense, row-major), mirroring `xla::Literal`.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    storage: Storage,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { storage: T::store(data), dims: vec![data.len() as i64] }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        let have = self.storage.len() as i64;
        if want != have {
            return Err(Error::Literal(format!(
                "reshape to {dims:?} wants {want} elements, literal has {have}"
            )));
        }
        Ok(Literal { storage: self.storage.clone(), dims: dims.to_vec() })
    }

    /// Typed copy of the elements; errors on dtype mismatch.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::load(&self.storage).ok_or_else(|| {
            Error::Literal(format!(
                "dtype mismatch: literal holds {}, caller wants {}",
                self.storage.dtype_name(),
                T::dtype_name()
            ))
        })
    }

    /// Unwrap a 1-tuple result. Stub literals are never tuples — this is
    /// only reachable through `execute`, which the stub cannot perform.
    pub fn to_tuple1(self) -> Result<Literal> {
        Err(Error::Unavailable("to_tuple1 on a stub literal"))
    }

    /// Dimension sizes.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Total element count.
    pub fn element_count(&self) -> usize {
        self.storage.len()
    }
}

// ---------------------------------------------------------------------------
// Runtime surface: every entry point reports unavailable
// ---------------------------------------------------------------------------

/// Stub PJRT client. [`PjRtClient::cpu`] always errors; the type exists so
/// call sites compile unchanged.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    /// Create a CPU client — always unavailable in the stub.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::Unavailable("PjRtClient::cpu"))
    }

    /// Backend platform name.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation — always unavailable in the stub.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable("PjRtClient::compile"))
    }
}

/// Stub HLO module proto.
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    /// Parse an HLO text file — always unavailable in the stub.
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        let _ = path.as_ref();
        Err(Error::Unavailable("HloModuleProto::from_text_file"))
    }
}

/// Stub computation handle.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    /// Wrap a module proto.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// Stub device buffer returned by `execute`.
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    /// Copy device memory back to a host literal — unavailable in the stub.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Stub compiled executable.
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    /// Execute with the given arguments — always unavailable in the stub.
    /// Generic so `execute::<xla::Literal>(…)` call sites compile as with
    /// the real crate.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("PjRtLoadedExecutable::execute"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(lit.dims(), &[4]);
        let r = lit.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn literal_dtype_and_shape_errors() {
        let lit = Literal::vec1(&[1i32, 2]);
        assert!(lit.to_vec::<f32>().is_err(), "i32 literal must not read as f32");
        assert!(lit.reshape(&[3]).is_err(), "2 elements cannot reshape to [3]");
    }

    #[test]
    fn runtime_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub client must be unavailable");
        let msg = err.to_string();
        assert!(msg.contains("unavailable"), "{msg}");
        assert!(msg.contains("stub"), "{msg}");
    }
}
