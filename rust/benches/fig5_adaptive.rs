//! Fig 5 reproduction: QuantPipe adapting the bitwidth to unannounced
//! bandwidth changes over five phases.
//!
//! Protocol (paper §4.2): the link between stage1 and stage2 is re-shaped
//! at phase boundaries; the controller sees only its own window
//! measurements. Tracks reported per window: measured bandwidth, output
//! rate, bitwidth, link utilization + the model-accuracy track.
//!
//! **Bandwidth scaling** (DESIGN.md §Substitutions): the paper's absolute
//! Mbps values encode *their* testbed's compute:communication ratio
//! (ViT-Base on Jetson ≈ 100 img/s vs our ViT-Tiny ≈ 1.4k img/s). We keep
//! the paper's *shape* — nominal → mild constraint (16-bit) → severe
//! (2-bit) → partial recovery (8-bit) → nominal — by deriving each phase's
//! capacity from the measured compute ceiling and Eq. 2's own thresholds:
//! `B_min(q) = full_bits·(q/32) / (S/R)`.

use quantpipe::adapt::AdaptConfig;
use quantpipe::benchkit::{hlo_spec, load_artifacts, section, write_bench_json, Table};
use quantpipe::config::Config;
use quantpipe::net::trace::BandwidthTrace;
use quantpipe::pipeline::{run, LinkQuant, Workload};
use quantpipe::quant::Method;
use quantpipe::util::json::Value;

fn main() -> quantpipe::Result<()> {
    let (manifest, dir, eval) = load_artifacts()?;
    let mut cfg = Config::default();

    // Window scaled down (50 → 10) together with phase length (200 → 60
    // microbatches) to keep the bench minutes-scale; ratios preserved.
    let window = 10u64;
    let phase_mb = 60u64;
    cfg.adapt.window = window;
    let n_links = manifest.stages.len() - 1;
    let s = manifest.microbatch;
    let total = 5 * phase_mb;

    // Nominal compute ceiling from per-stage compute times (steady state).
    let probe = hlo_spec(
        &manifest, &dir, &cfg,
        vec![BandwidthTrace::unlimited(); n_links],
        LinkQuant { method: Method::Pda, initial_bits: 32, ..Default::default() },
        None,
    );
    let probe_rep = run(probe, Workload::repeat(eval.clone(), s, 30))?;
    let max_stage = probe_rep
        .stage_compute_s
        .iter()
        .cloned()
        .fold(0.0f64, f64::max)
        .max(1e-6);
    let nominal = s as f64 / max_stage;
    let target = nominal * 0.75;

    // Eq.2 threshold: capacity needed to hold bitwidth q at target rate.
    let full_bits = manifest.activation_shape.iter().product::<usize>() as f64 * 32.0;
    let budget_secs = s as f64 / target;
    let b_min = |q: f64| full_bits * (q / 32.0) / budget_secs;

    // Phases: nominal → just under the 32-bit threshold (→16) → just above
    // the 2-bit threshold (→2) → between 8- and 16-bit thresholds (→8) →
    // nominal. Same qualitative schedule as the paper's ∞/400/50/200/∞.
    let p1 = b_min(32.0) * 0.85;
    let p2 = b_min(2.0) * 1.15;
    let p3 = b_min(8.0) * 1.2;

    // Phase wall-clock: time for phase_mb microbatches at the SLOWEST
    // phase (p2 at 2-bit ≈ budget-limited) with margin.
    let phase_secs = budget_secs * phase_mb as f64 * 1.3;

    section("Fig 5: adaptivity to dynamic bandwidth (five phases)");
    println!(
        "nominal {:.0} img/s, target R = {:.0} img/s, window {window} mb, phase ≈ {phase_secs:.1}s",
        nominal, target
    );
    println!(
        "phase capacities (scaled to this testbed): inf / {:.0} / {:.1} / {:.0} Mbps / inf",
        p1 / 1e6,
        p2 / 1e6,
        p3 / 1e6
    );

    let mut traces = vec![BandwidthTrace::unlimited(); n_links];
    traces[0] = BandwidthTrace::from_points(&[
        (0.0, f64::INFINITY),
        (phase_secs, p1),
        (2.0 * phase_secs, p2),
        (3.0 * phase_secs, p3),
        (4.0 * phase_secs, f64::INFINITY),
    ]);

    let adapt = AdaptConfig {
        target_rate: target,
        microbatch: s,
        policy: quantpipe::adapt::Policy::Ladder,
        raise_margin: 1.1,
    };
    let spec = hlo_spec(
        &manifest, &dir, &cfg,
        traces,
        LinkQuant { method: Method::Pda, initial_bits: 32, ..Default::default() },
        Some(adapt),
    );
    let report = run(spec, Workload::repeat(eval.clone(), s, total))?;

    let mut table = Table::new(&["t(s)", "bw meas (Mbps)", "rate (img/s)", "bits", "util"]);
    for p in report.timeline.points.iter().filter(|p| p.stage == 0) {
        table.row(&[
            format!("{:.1}", p.t),
            if p.bandwidth_bps.is_infinite() {
                "inf".into()
            } else {
                format!("{:.1}", p.bandwidth_bps / 1e6)
            },
            format!("{:.0}", p.rate),
            format!("{}", p.bits),
            format!("{:.2}", p.util),
        ]);
    }
    table.print();

    println!("\nbitwidth sequence (link 0): {:?}", report.timeline.bits_sequence(0));
    println!(
        "overall throughput {:.1} img/s, accuracy {:.2}%",
        report.throughput,
        report.accuracy * 100.0
    );
    print!("window accuracy track: ");
    for (t, a) in &report.window_accuracy {
        print!("({t:.0}s {:.0}%) ", a * 100.0);
    }
    println!();
    std::fs::write("fig5_timeline.csv", report.timeline.to_csv())?;
    println!("timeline -> fig5_timeline.csv");

    // Machine-readable result for the perf trajectory: the adaptive run's
    // headline numbers plus the bitwidth track, in one parseable file.
    let bits_seq = Value::Arr(
        report
            .timeline
            .bits_sequence(0)
            .iter()
            .map(|&b| Value::Num(b as f64))
            .collect(),
    );
    let bench_path = write_bench_json(
        "fig5",
        &[
            ("throughput_img_s", report.throughput),
            ("accuracy", report.accuracy),
            ("wall_secs", report.wall_secs),
            ("microbatches", report.microbatches as f64),
            ("images", report.images as f64),
            ("target_rate_img_s", target),
            ("nominal_img_s", nominal),
            ("p50_latency_s", report.latency.quantile(0.5).as_secs_f64()),
            ("p99_latency_s", report.latency.quantile(0.99).as_secs_f64()),
            ("final_bits_link0", report.timeline.final_bits(0).unwrap_or(32) as f64),
            ("bits_steps_link0", report.timeline.bits_sequence(0).len() as f64),
            ("window_points", report.timeline.points.len() as f64),
        ],
        &[("bits_sequence_link0", bits_seq)],
    )?;
    println!("bench json -> {}", bench_path.display());
    println!("\npaper's track: 32 → 16 → 2 → 6 → 8 → 32 with the rate recovering each phase.");
    Ok(())
}
