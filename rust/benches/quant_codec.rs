//! Hot-path microbenchmarks: quantize/dequantize (native vs AOT-Pallas
//! HLO), bit pack/unpack, calibration (including the DS search), end-to-end
//! codec — plus the paper's "<1% DS-ACIQ overhead" check against measured
//! stage compute.

use quantpipe::benchkit::{fmt_dur, load_artifacts, section, time, Table};
use quantpipe::quant::codec::{Codec, NativeBackend, QuantBackend};
use quantpipe::quant::ds_aciq::{ds_aciq_b, DEFAULT_STEPS};
use quantpipe::quant::{calibrate, pack, uniform, Method};
use quantpipe::runtime::{Engine, HloQuantBackend};
use quantpipe::tensor::Tensor;
use quantpipe::util::rng::Rng;

fn main() -> quantpipe::Result<()> {
    let (manifest, dir, eval) = load_artifacts()?;
    let rows = manifest.quant.rows;
    let cols = manifest.quant.cols;
    let n = rows * cols;
    let mut rng = Rng::seed(11);
    let x = rng.laplace_vec(n, 1.3);
    let bytes = (n * 4) as f64;

    section("codec microbenchmarks");
    println!("activation: {rows}x{cols} = {n} f32 ({:.0} KB)", bytes / 1024.0);

    let mut table = Table::new(&["op", "mean", "GB/s", "notes"]);

    // --- native quantize/dequantize -------------------------------------------
    let p8 = calibrate(&x, Method::Aciq, 8);
    let mut codes = vec![0i32; n];
    let (mean, _, _) = time(3, 20, || uniform::quantize_into(&x, &p8, &mut codes));
    table.row(&["quantize (native)".into(), fmt_dur(mean), format!("{:.2}", bytes / mean.as_secs_f64() / 1e9), "8-bit aciq".into()]);

    let mut back = vec![0f32; n];
    let (mean, _, _) = time(3, 20, || uniform::dequantize_into(&codes, &p8, &mut back));
    table.row(&["dequantize (native)".into(), fmt_dur(mean), format!("{:.2}", bytes / mean.as_secs_f64() / 1e9), "".into()]);

    // --- bit packing -----------------------------------------------------------
    for bits in [2u8, 4, 6, 8, 16] {
        let p = calibrate(&x, Method::Aciq, bits);
        let cs = uniform::quantize(&x, &p);
        let mut buf = Vec::new();
        let (mean, _, _) = time(3, 20, || pack::pack(&cs, bits, p.pack_offset(), &mut buf));
        table.row(&[
            format!("pack {bits}-bit"),
            fmt_dur(mean),
            format!("{:.2}", bytes / mean.as_secs_f64() / 1e9),
            format!("{}x compression", 32 / bits),
        ]);
        let mut out = Vec::new();
        let (mean, _, _) = time(3, 20, || pack::unpack(&buf, n, bits, p.pack_offset(), &mut out).unwrap());
        table.row(&[format!("unpack {bits}-bit"), fmt_dur(mean), format!("{:.2}", bytes / mean.as_secs_f64() / 1e9), "".into()]);
    }

    // --- calibration -----------------------------------------------------------
    let (mean_aciq, _, _) = time(3, 20, || {
        let _ = calibrate(&x, Method::Aciq, 8);
    });
    table.row(&["calibrate aciq".into(), fmt_dur(mean_aciq), format!("{:.2}", bytes / mean_aciq.as_secs_f64() / 1e9), "mean|x| pass".into()]);
    let (mean_ds_exact, _, _) = time(3, 10, || {
        let _ = ds_aciq_b(&x, 2, DEFAULT_STEPS);
    });
    table.row(&["calibrate ds-aciq (exact)".into(), fmt_dur(mean_ds_exact), format!("{:.2}", bytes / mean_ds_exact.as_secs_f64() / 1e9), "full hist + 100-step search".into()]);
    let (mean_ds, _, _) = time(3, 10, || {
        let _ = calibrate(&x, quantpipe::quant::Method::DsAciq, 2);
    });
    table.row(&["calibrate ds-aciq (deployed)".into(), fmt_dur(mean_ds), format!("{:.2}", bytes / mean_ds.as_secs_f64() / 1e9), "16k-sample fast path".into()]);

    // --- end-to-end codec --------------------------------------------------------
    // Recycling the payload buffer makes steady-state encoding
    // allocation-free (the driver's stage loop does the same).
    let mut codec = Codec::default();
    for bits in [2u8, 8] {
        let (mean, _, _) = time(3, 10, || {
            let enc = codec.encode(&x, Method::Pda, bits).unwrap();
            std::hint::black_box(&enc);
            codec.recycle(enc);
        });
        table.row(&[format!("encode e2e {bits}-bit (pda)"), fmt_dur(mean), format!("{:.2}", bytes / mean.as_secs_f64() / 1e9), "calib+quant+pack, recycled".into()]);
    }

    // --- HLO (AOT Pallas kernel) backend ----------------------------------------
    let engine = Engine::cpu()?;
    let mut hlo = HloQuantBackend::load(&engine, &dir, &manifest)?;
    let (mean_hq, _, _) = time(2, 10, || {
        hlo.quantize(&x, &p8, &mut codes).unwrap();
    });
    table.row(&["quantize (hlo-pallas)".into(), fmt_dur(mean_hq), format!("{:.2}", bytes / mean_hq.as_secs_f64() / 1e9), "PJRT execute".into()]);
    let (mean_hd, _, _) = time(2, 10, || {
        hlo.dequantize(&codes, &p8, &mut back).unwrap();
    });
    table.row(&["dequantize (hlo-pallas)".into(), fmt_dur(mean_hd), format!("{:.2}", bytes / mean_hd.as_secs_f64() / 1e9), "".into()]);

    // --- stage compute for the paper's <1% claim ------------------------------------
    let stage0 = engine.load_hlo(dir.join(&manifest.stages[0].file))?;
    let img = eval.microbatch(0, manifest.microbatch);
    let out_shape = manifest.stages[0].out_shape.clone();
    let (mean_stage, _, _) = time(2, 10, || {
        let _ = stage0.run_f32(&[&img], &out_shape).unwrap();
    });
    table.row(&["stage 0 compute".into(), fmt_dur(mean_stage), "".into(), "2-block ViT shard".into()]);
    table.print();

    let overhead = mean_ds.as_secs_f64() / mean_stage.as_secs_f64() * 100.0;
    println!("\nDS-ACIQ (deployed) overhead vs stage compute here: {overhead:.2}%");
    // The paper's <1% claim is at THEIR compute scale: ViT-Base on Jetson
    // ≈ 640 ms per 64-image microbatch vs our tiny model's ~8 ms.
    let paper_scale = mean_ds.as_secs_f64() / 0.64 * 100.0;
    println!("same absolute cost at the paper's stage compute (~640 ms): {paper_scale:.3}%  (paper claims <1%)");

    // HLO-vs-native code agreement (semantics check, not speed).
    let mut c_native = vec![0i32; n];
    NativeBackend.quantize(&x, &p8, &mut c_native)?;
    let mut c_hlo = vec![0i32; n];
    hlo.quantize(&x, &p8, &mut c_hlo)?;
    let diff = c_native.iter().zip(&c_hlo).filter(|(a, b)| a != b).count();
    println!("native vs hlo code agreement: {}/{} differ ({:.4}%)", diff, n, diff as f64 / n as f64 * 100.0);

    let _ = Tensor::zeros(&[1]); // keep Tensor linked for doc example parity
    Ok(())
}
