//! Hot-path microbenchmarks: the fused single-pass codec kernels vs the
//! legacy two-pass (quantize→i32→pack / unpack→i32→dequantize) reference,
//! multicore encode scaling, fused-vs-unfused calibration — plus the
//! artifact-dependent sections (native vs AOT-Pallas HLO arithmetic and
//! the paper's "<1% DS-ACIQ overhead" check against measured stage
//! compute), which skip with a notice when `make artifacts` hasn't run.
//!
//! Emits `BENCH_hotpath.json` (ns/elem per bitwidth for encode and decode,
//! fused vs legacy measured in the same run) for CI/perf tooling. The
//! fused payloads are asserted byte-identical to the legacy ones before
//! anything is timed.

use quantpipe::benchkit::{
    fmt_dur, load_artifacts, print_delta_vs_committed, section, time, write_bench_json, Table,
};
use quantpipe::quant::codec::{Codec, NativeBackend, QuantBackend};
use quantpipe::quant::ds_aciq::{ds_aciq_b, DEFAULT_STEPS};
use quantpipe::quant::stats::{AbsHistogram, CalibScan, DEFAULT_BINS};
use quantpipe::quant::{aciq, calibrate, fused, pack, uniform, Method, SUPPORTED_BITS};
use quantpipe::runtime::{Engine, HloQuantBackend};
use quantpipe::tensor::Tensor;
use quantpipe::util::rng::Rng;
use std::time::Duration;

/// The 131k-element boundary activation (the acceptance workload).
const HOT_ELEMS: usize = 131_072;

fn ns_per_elem(mean: Duration, n: usize) -> f64 {
    mean.as_secs_f64() * 1e9 / n.max(1) as f64
}

fn main() -> quantpipe::Result<()> {
    hotpath_bench()?;
    // Artifact-dependent sections (PJRT + AOT HLO shards).
    match load_artifacts() {
        Ok((manifest, dir, eval)) => hlo_bench(manifest, dir, eval)?,
        Err(e) => {
            println!("\n[skip] HLO/stage-compute sections (run `make artifacts`): {e:#}");
        }
    }
    Ok(())
}

/// Fused vs legacy codec paths, no artifacts needed.
fn hotpath_bench() -> quantpipe::Result<()> {
    let n = HOT_ELEMS;
    let mut rng = Rng::seed(11);
    let x = rng.laplace_vec(n, 1.3);
    let bytes = (n * 4) as f64;
    let mt = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1)
        .min(8);
    // encode_into_mt caps workers at one per MT_MIN_CHUNK_ELEMS elements,
    // so report the parallelism this tensor actually gets — not the knob.
    let mt_workers = mt.min(n / fused::MT_MIN_CHUNK_ELEMS).max(1);

    section("codec hot path: fused single-pass vs legacy two-pass");
    println!(
        "activation: {n} f32 ({:.0} KB); mt encode: codec_threads = {mt} -> {mt_workers} \
         effective workers (>=64k elems each)",
        bytes / 1024.0
    );

    let mut table = Table::new(&["op", "legacy", "fused", "speedup", "fused-mt"]);
    let mut fields: Vec<(String, f64)> = vec![
        ("elems".into(), n as f64),
        ("mt_effective_workers".into(), mt_workers as f64),
    ];

    let mut codes = vec![0i32; n];
    let mut legacy_buf = Vec::new();
    let mut fused_buf = Vec::new();
    let mut mt_buf = Vec::new();
    let mut legacy_out = vec![0f32; n];
    let mut fused_out = vec![0f32; n];

    for bits in SUPPORTED_BITS {
        let p = calibrate(&x, Method::Aciq, bits);
        let off = p.pack_offset();

        // Correctness first: fused must be byte-identical to legacy (and
        // parallel to serial) before any timing means anything.
        uniform::quantize_into(&x, &p, &mut codes);
        pack::pack(&codes, bits, off, &mut legacy_buf);
        fused::encode_into(&x, &p, &mut fused_buf);
        assert_eq!(fused_buf, legacy_buf, "fused encode diverged at {bits}-bit");
        fused::encode_into_mt(&x, &p, mt, &mut mt_buf);
        assert_eq!(mt_buf, legacy_buf, "parallel encode diverged at {bits}-bit");
        pack::unpack(&legacy_buf, n, bits, off, &mut codes)?;
        uniform::dequantize_into(&codes, &p, &mut legacy_out);
        fused::decode_into(&legacy_buf, &p, &mut fused_out)?;
        assert_eq!(fused_out, legacy_out, "fused decode diverged at {bits}-bit");

        let (enc_legacy, _, _) = time(3, 20, || {
            uniform::quantize_into(&x, &p, &mut codes);
            pack::pack(&codes, bits, off, &mut legacy_buf);
        });
        let (enc_fused, _, _) = time(3, 20, || fused::encode_into(&x, &p, &mut fused_buf));
        let (enc_mt, _, _) = time(3, 20, || fused::encode_into_mt(&x, &p, mt, &mut mt_buf));
        table.row(&[
            format!("encode {bits}-bit"),
            fmt_dur(enc_legacy),
            fmt_dur(enc_fused),
            format!("{:.2}x", enc_legacy.as_secs_f64() / enc_fused.as_secs_f64()),
            fmt_dur(enc_mt),
        ]);

        let (dec_legacy, _, _) = time(3, 20, || {
            pack::unpack(&legacy_buf, n, bits, off, &mut codes).unwrap();
            uniform::dequantize_into(&codes, &p, &mut legacy_out);
        });
        let (dec_fused, _, _) =
            time(3, 20, || fused::decode_into(&legacy_buf, &p, &mut fused_out).unwrap());
        table.row(&[
            format!("decode {bits}-bit"),
            fmt_dur(dec_legacy),
            fmt_dur(dec_fused),
            format!("{:.2}x", dec_legacy.as_secs_f64() / dec_fused.as_secs_f64()),
            "".into(),
        ]);

        fields.push((format!("encode_legacy_ns_per_elem_b{bits}"), ns_per_elem(enc_legacy, n)));
        fields.push((format!("encode_fused_ns_per_elem_b{bits}"), ns_per_elem(enc_fused, n)));
        fields.push((format!("encode_fused_mt_ns_per_elem_b{bits}"), ns_per_elem(enc_mt, n)));
        fields.push((format!("decode_legacy_ns_per_elem_b{bits}"), ns_per_elem(dec_legacy, n)));
        fields.push((format!("decode_fused_ns_per_elem_b{bits}"), ns_per_elem(dec_fused, n)));
        let combined_legacy = ns_per_elem(enc_legacy, n) + ns_per_elem(dec_legacy, n);
        let combined_fused = ns_per_elem(enc_fused, n) + ns_per_elem(dec_fused, n);
        fields.push((format!("combined_legacy_ns_per_elem_b{bits}"), combined_legacy));
        fields.push((format!("combined_fused_ns_per_elem_b{bits}"), combined_fused));
        fields.push((format!("combined_speedup_b{bits}"), combined_legacy / combined_fused));
    }

    // Raw f32 passthrough: bulk copy vs what the wire actually carries.
    let mut codec = Codec::default();
    let (raw, _, _) = time(3, 20, || {
        let enc = codec.encode(&x, Method::Pda, 32).unwrap();
        std::hint::black_box(&enc);
        codec.recycle(enc);
    });
    table.row(&[
        "raw f32 passthrough".into(),
        "".into(),
        fmt_dur(raw),
        "".into(),
        "".into(),
    ]);
    fields.push(("raw_passthrough_ns_per_elem".into(), ns_per_elem(raw, n)));

    // Calibration: the fused stats+histogram scan vs the three separate
    // passes it replaced (mean|x|, |x|-max, binning).
    let (calib_legacy, _, _) = time(3, 10, || {
        let b_e = aciq::laplace_b(&x);
        let h = AbsHistogram::compute(&x, DEFAULT_BINS);
        std::hint::black_box((b_e, h.total));
    });
    let (calib_fused, _, _) = time(3, 10, || {
        let scan = CalibScan::compute(&x, DEFAULT_BINS);
        std::hint::black_box((scan.b_e(), scan.hist.total));
    });
    table.row(&[
        "calib scan (stats+hist)".into(),
        fmt_dur(calib_legacy),
        fmt_dur(calib_fused),
        format!("{:.2}x", calib_legacy.as_secs_f64() / calib_fused.as_secs_f64()),
        "".into(),
    ]);
    fields.push(("calib_legacy_ns_per_elem".into(), ns_per_elem(calib_legacy, n)));
    fields.push(("calib_fused_ns_per_elem".into(), ns_per_elem(calib_fused, n)));

    // End-to-end codec (calibrate + encode, recycled payload — what the
    // driver's stage loop actually runs).
    for bits in [2u8, 8] {
        let (mean, _, _) = time(3, 10, || {
            let enc = codec.encode(&x, Method::Pda, bits).unwrap();
            std::hint::black_box(&enc);
            codec.recycle(enc);
        });
        table.row(&[
            format!("encode e2e {bits}-bit (pda)"),
            "".into(),
            fmt_dur(mean),
            "".into(),
            "".into(),
        ]);
        fields.push((format!("encode_e2e_pda_ns_per_elem_b{bits}"), ns_per_elem(mean, n)));
    }
    table.print();

    let speedup4 = fields
        .iter()
        .find(|(k, _)| k.as_str() == "combined_speedup_b4")
        .map(|&(_, v)| v)
        .unwrap_or(0.0);
    println!("\ncombined encode+decode speedup at 4-bit (fused vs legacy): {speedup4:.2}x");

    simd_bench(&x, &mut fields);
    tiled_bench(&x, &mut fields)?;

    let borrowed: Vec<(&str, f64)> = fields.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    print_delta_vs_committed("hotpath", &borrowed);
    let extra = [(
        "simd",
        quantpipe::util::json::Value::Str(fused::simd_active().into()),
    )];
    let path = write_bench_json("hotpath", &borrowed, &extra)?;
    println!("bench json -> {}", path.display());
    Ok(())
}

/// Scalar vs SIMD fused kernels on this machine's detected ISA. Both
/// paths are byte-identical by contract (asserted before timing); the
/// speedup assertion soft-fails with a notice when no vector ISA is
/// detected, so the bench stays runnable on any target.
fn simd_bench(x: &[f32], fields: &mut Vec<(String, f64)>) {
    let n = x.len();
    section("fused kernels: scalar vs SIMD");
    let isa = fused::simd_active();
    println!("detected ISA: {isa}");
    let mut table = Table::new(&["op", "scalar", "simd", "speedup"]);
    let mut scalar_buf = Vec::new();
    let mut simd_buf = Vec::new();
    let mut out = vec![0f32; n];

    for bits in [2u8, 4, 8] {
        let p = calibrate(x, Method::Aciq, bits);
        fused::set_simd_enabled(false);
        fused::encode_into(x, &p, &mut scalar_buf);
        fused::set_simd_enabled(true);
        fused::encode_into(x, &p, &mut simd_buf);
        assert_eq!(simd_buf, scalar_buf, "SIMD encode diverged at {bits}-bit");

        fused::set_simd_enabled(false);
        let (enc_scalar, enc_scalar_min, _) =
            time(3, 20, || fused::encode_into(x, &p, &mut scalar_buf));
        let (dec_scalar, _, _) =
            time(3, 20, || fused::decode_into(&scalar_buf, &p, &mut out).unwrap());
        fused::set_simd_enabled(true);
        let (enc_simd, enc_simd_min, _) =
            time(3, 20, || fused::encode_into(x, &p, &mut simd_buf));
        let (dec_simd, _, _) =
            time(3, 20, || fused::decode_into(&simd_buf, &p, &mut out).unwrap());

        table.row(&[
            format!("encode {bits}-bit"),
            fmt_dur(enc_scalar),
            fmt_dur(enc_simd),
            format!("{:.2}x", enc_scalar.as_secs_f64() / enc_simd.as_secs_f64()),
        ]);
        table.row(&[
            format!("decode {bits}-bit"),
            fmt_dur(dec_scalar),
            fmt_dur(dec_simd),
            format!("{:.2}x", dec_scalar.as_secs_f64() / dec_simd.as_secs_f64()),
        ]);
        fields.push((format!("encode_scalar_ns_per_elem_b{bits}"), ns_per_elem(enc_scalar, n)));
        fields.push((format!("encode_simd_ns_per_elem_b{bits}"), ns_per_elem(enc_simd, n)));
        fields.push((format!("decode_scalar_ns_per_elem_b{bits}"), ns_per_elem(dec_scalar, n)));
        fields.push((format!("decode_simd_ns_per_elem_b{bits}"), ns_per_elem(dec_simd, n)));
        fields.push((
            format!("simd_encode_speedup_b{bits}"),
            enc_scalar.as_secs_f64() / enc_simd.as_secs_f64(),
        ));

        if isa == "scalar" {
            println!(
                "[notice] no SIMD ISA detected on this CPU — skipping the \
                 {bits}-bit speedup assertion (scalar fallback is the kernel)"
            );
        } else {
            // Best-of-run comparison absorbs scheduler noise; the vector
            // kernels are well over 25% faster wherever they exist.
            assert!(
                enc_simd_min.as_secs_f64() <= enc_scalar_min.as_secs_f64() * 1.25,
                "SIMD encode ({isa}) slower than scalar at {bits}-bit: {:?} vs {:?}",
                enc_simd_min,
                enc_scalar_min
            );
        }
    }
    table.print();
}

/// Tiled hybrid codec vs the flat single-tensor path: wire cost and
/// measured quantization MSE at the sub-byte widths where tiling earns
/// its param-table overhead.
fn tiled_bench(x: &[f32], fields: &mut Vec<(String, f64)>) -> quantpipe::Result<()> {
    use quantpipe::quant::tile::{TileCodec, TileConfig};
    let n = x.len();
    section("tiled hybrid codec vs flat");
    let cfg = TileConfig { tile_elems: 8192, outlier_frac: 0.01 };
    println!(
        "tiles: {} x {} elems, outlier_frac {}",
        n.div_ceil(cfg.tile_elems),
        cfg.tile_elems,
        cfg.outlier_frac
    );
    let mut table = Table::new(&["op", "flat", "tiled", "wire bits/elem (tiled)"]);
    let mut flat_codec = Codec::default();
    let mut tiled_codec = Codec::default();
    tiled_codec.set_tiling(Some(TileCodec::new(cfg, Method::Pda)));
    let mut out = vec![0f32; n];
    let mse = |a: &[f32], b: &[f32]| {
        a.iter().zip(b).map(|(p, q)| ((p - q) as f64).powi(2)).sum::<f64>() / a.len() as f64
    };

    for bits in [2u8, 4] {
        let (flat_t, _, _) = time(3, 10, || {
            let enc = flat_codec.encode(x, Method::Pda, bits).unwrap();
            std::hint::black_box(&enc);
            flat_codec.recycle(enc);
        });
        let (tiled_t, _, _) = time(3, 10, || {
            let enc = tiled_codec.encode_tiled(x, bits, None).unwrap();
            std::hint::black_box(&enc);
            tiled_codec.recycle(enc);
        });
        let enc = tiled_codec.encode_tiled(x, bits, None)?;
        let wire_bits = enc.avg_wire_bits();
        tiled_codec.decode(&enc, &mut out)?;
        let tiled_mse = mse(x, &out);
        let flat_enc = flat_codec.encode(x, Method::Pda, bits)?;
        flat_codec.decode(&flat_enc, &mut out)?;
        let flat_mse = mse(x, &out);

        table.row(&[
            format!("encode e2e {bits}-bit"),
            fmt_dur(flat_t),
            fmt_dur(tiled_t),
            format!("{wire_bits:.2}"),
        ]);
        table.row(&[
            format!("quant MSE {bits}-bit"),
            format!("{flat_mse:.3e}"),
            format!("{tiled_mse:.3e}"),
            "".into(),
        ]);
        fields.push((format!("encode_flat_e2e_ns_per_elem_b{bits}"), ns_per_elem(flat_t, n)));
        fields.push((format!("encode_tiled_e2e_ns_per_elem_b{bits}"), ns_per_elem(tiled_t, n)));
        fields.push((format!("tiled_wire_bits_per_elem_b{bits}"), wire_bits));
        fields.push((format!("flat_mse_b{bits}"), flat_mse));
        fields.push((format!("tiled_mse_b{bits}"), tiled_mse));
    }
    table.print();
    Ok(())
}

/// Native vs AOT-Pallas HLO arithmetic + the paper's <1% DS overhead
/// check (needs `make artifacts`).
fn hlo_bench(
    manifest: quantpipe::runtime::Manifest,
    dir: std::path::PathBuf,
    eval: std::sync::Arc<quantpipe::data::EvalSet>,
) -> quantpipe::Result<()> {
    let rows = manifest.quant.rows;
    let cols = manifest.quant.cols;
    let n = rows * cols;
    let mut rng = Rng::seed(11);
    let x = rng.laplace_vec(n, 1.3);
    let bytes = (n * 4) as f64;

    section("HLO (AOT Pallas kernel) backend");
    println!("activation: {rows}x{cols} = {n} f32 ({:.0} KB)", bytes / 1024.0);

    let mut table = Table::new(&["op", "mean", "GB/s", "notes"]);
    let p8 = calibrate(&x, Method::Aciq, 8);
    let mut codes = vec![0i32; n];
    let mut back = vec![0f32; n];

    // Calibration cost context (exact vs deployed DS search).
    let (mean_ds_exact, _, _) = time(3, 10, || {
        let _ = ds_aciq_b(&x, 2, DEFAULT_STEPS);
    });
    table.row(&["calibrate ds-aciq (exact)".into(), fmt_dur(mean_ds_exact), format!("{:.2}", bytes / mean_ds_exact.as_secs_f64() / 1e9), "full hist + 100-step search".into()]);
    let (mean_ds, _, _) = time(3, 10, || {
        let _ = calibrate(&x, Method::DsAciq, 2);
    });
    table.row(&["calibrate ds-aciq (deployed)".into(), fmt_dur(mean_ds), format!("{:.2}", bytes / mean_ds.as_secs_f64() / 1e9), "16k-sample fast path".into()]);

    let engine = Engine::cpu()?;
    let mut hlo = HloQuantBackend::load(&engine, &dir, &manifest)?;
    let (mean_hq, _, _) = time(2, 10, || {
        hlo.quantize(&x, &p8, &mut codes).unwrap();
    });
    table.row(&["quantize (hlo-pallas)".into(), fmt_dur(mean_hq), format!("{:.2}", bytes / mean_hq.as_secs_f64() / 1e9), "PJRT execute".into()]);
    let (mean_hd, _, _) = time(2, 10, || {
        hlo.dequantize(&codes, &p8, &mut back).unwrap();
    });
    table.row(&["dequantize (hlo-pallas)".into(), fmt_dur(mean_hd), format!("{:.2}", bytes / mean_hd.as_secs_f64() / 1e9), "".into()]);

    // Stage compute for the paper's <1% claim.
    let stage0 = engine.load_hlo(dir.join(&manifest.stages[0].file))?;
    let img = eval.microbatch(0, manifest.microbatch);
    let out_shape = manifest.stages[0].out_shape.clone();
    let (mean_stage, _, _) = time(2, 10, || {
        let _ = stage0.run_f32(&[&img], &out_shape).unwrap();
    });
    table.row(&["stage 0 compute".into(), fmt_dur(mean_stage), "".into(), "2-block ViT shard".into()]);
    table.print();

    let overhead = mean_ds.as_secs_f64() / mean_stage.as_secs_f64() * 100.0;
    println!("\nDS-ACIQ (deployed) overhead vs stage compute here: {overhead:.2}%");
    // The paper's <1% claim is at THEIR compute scale: ViT-Base on Jetson
    // ≈ 640 ms per 64-image microbatch vs our tiny model's ~8 ms.
    let paper_scale = mean_ds.as_secs_f64() / 0.64 * 100.0;
    println!("same absolute cost at the paper's stage compute (~640 ms): {paper_scale:.3}%  (paper claims <1%)");

    // HLO-vs-native code agreement (semantics check, not speed).
    let mut c_native = vec![0i32; n];
    NativeBackend.quantize(&x, &p8, &mut c_native)?;
    let mut c_hlo = vec![0i32; n];
    hlo.quantize(&x, &p8, &mut c_hlo)?;
    let diff = c_native.iter().zip(&c_hlo).filter(|(a, b)| a != b).count();
    println!("native vs hlo code agreement: {}/{} differ ({:.4}%)", diff, n, diff as f64 / n as f64 * 100.0);

    let _ = Tensor::zeros(&[1]); // keep Tensor linked for doc example parity
    Ok(())
}
