//! Fig 3 reproduction: boundary-activation distributions before and after
//! quantization, naive PTQ vs ACIQ, at two partition boundaries.
//!
//! The paper plots histograms of the original data (top), after naive PTQ
//! (middle) and after ACIQ (bottom) for the activations after blocks 4 and
//! 6. We print ASCII histograms plus the quantitative story: naive's
//! min/max range is blown up by outliers so its quantization interval is
//! orders of magnitude wider than ACIQ's, destroying small values (most of
//! the mass rounds to zero).

use quantpipe::benchkit::{load_artifacts, section, Table};
use quantpipe::data::load_calib;
use quantpipe::quant::stats::TensorStats;
use quantpipe::quant::{calibrate, uniform, Method};

fn ascii_hist(x: &[f32], lo: f32, hi: f32, bins: usize, rows: usize) -> Vec<String> {
    let mut counts = vec![0u64; bins];
    let w = (hi - lo) / bins as f32;
    for &v in x {
        if v >= lo && v < hi {
            counts[((v - lo) / w) as usize % bins] += 1;
        }
    }
    let max = *counts.iter().max().unwrap_or(&1) as f64;
    let mut out = Vec::new();
    for r in (0..rows).rev() {
        let thr = max * (r as f64 + 0.5) / rows as f64;
        let line: String = counts
            .iter()
            .map(|&c| if (c as f64) >= thr { '#' } else { ' ' })
            .collect();
        out.push(line);
    }
    out
}

fn zero_fraction(x: &[f32], scale: f32) -> f64 {
    // Fraction of values that quantize to code 0 (information destroyed).
    x.iter().filter(|v| (v.abs() / scale).round() == 0.0).count() as f64 / x.len() as f64
}

fn main() -> quantpipe::Result<()> {
    let (manifest, dir, _eval) = load_artifacts()?;
    let tensors = load_calib(dir.join(&manifest.calib.file))?;
    let q = 4u8; // the paper's Fig 3 regime: visible naive degradation

    section("Fig 3: activation distributions at partition boundaries");
    let mut table = Table::new(&[
        "boundary", "std", "max|x|", "kurtosis",
        "naive Δ", "aciq Δ", "naive→0", "aciq→0",
    ]);

    for (i, t) in tensors.iter().enumerate() {
        let x = &t.data;
        let stats = TensorStats::compute(x);
        let p_naive = calibrate(x, Method::Naive, q);
        let p_aciq = calibrate(x, Method::Aciq, q);
        table.row(&[
            format!("after block {}", manifest.stages[i].blocks[1]),
            format!("{:.2}", stats.std()),
            format!("{:.2}", stats.abs_max()),
            format!("{:.1}", stats.excess_kurtosis(x)),
            format!("{:.4}", p_naive.scale),
            format!("{:.4}", p_aciq.scale),
            format!("{:.1}%", zero_fraction(x, p_naive.scale) * 100.0),
            format!("{:.1}%", zero_fraction(x, p_aciq.scale) * 100.0),
        ]);
    }
    table.print();

    // ASCII histograms for the last boundary (the paper's "6th block").
    let t = tensors.last().expect("calib tensors");
    let x = &t.data;
    let stats = TensorStats::compute(x);
    let span = 4.0 * stats.std() as f32;
    println!("\noriginal distribution (|x| ≤ {span:.1}):");
    for line in ascii_hist(x, -span, span, 64, 6) {
        println!("  |{line}|");
    }
    let rt_naive = uniform::roundtrip(x, &calibrate(x, Method::Naive, q));
    println!("after naive {q}-bit PTQ:");
    for line in ascii_hist(&rt_naive, -span, span, 64, 6) {
        println!("  |{line}|");
    }
    let rt_aciq = uniform::roundtrip(x, &calibrate(x, Method::Aciq, q));
    println!("after ACIQ {q}-bit:");
    for line in ascii_hist(&rt_aciq, -span, span, 64, 6) {
        println!("  |{line}|");
    }
    println!("\nshape check: naive's interval (Δ) is far wider than ACIQ's, so most of");
    println!("the bulk rounds to zero under naive PTQ while ACIQ preserves it.");
    Ok(())
}
