//! Table 1 reproduction: top-1 accuracy for {naive PTQ, ACIQ, PDA} ×
//! {32, 16, 8, 6, 4, 2}-bit, every boundary activation quantized, one
//! pass over the held-out eval set through the real 4-stage HLO pipeline.
//!
//! Shape to match the paper (absolute numbers differ — ViT-Tiny-synthetic
//! vs ViT-Base/ImageNet): naive collapses at small bitwidths; ACIQ holds
//! to 4-bit and drops at 2-bit; PDA recovers a large fraction of the
//! 2-bit drop (paper: +15.85 pp).

use quantpipe::benchkit::{hlo_spec, load_artifacts, section, Table};
use quantpipe::config::Config;
use quantpipe::net::trace::BandwidthTrace;
use quantpipe::pipeline::{run, LinkQuant, Workload};
use quantpipe::quant::Method;

fn main() -> quantpipe::Result<()> {
    let (manifest, dir, eval) = load_artifacts()?;
    let cfg = Config::default();
    let bits = [32u8, 16, 8, 6, 4, 2];
    let methods = [Method::Naive, Method::Aciq, Method::Pda];

    section("Table 1: average model accuracy (top-1)");
    println!(
        "model: {:.2}M-param ViT, {} stages, eval {} images, fp32 = {:.2}%",
        manifest.model.params as f64 / 1e6,
        manifest.stages.len(),
        eval.count,
        manifest.model.fp32_top1 * 100.0
    );

    let mut table = Table::new(&["method", "32bit", "16bit", "8bit", "6bit", "4bit", "2bit"]);
    for method in methods {
        let mut cells = vec![method.name().to_string()];
        for &b in &bits {
            let traces = vec![BandwidthTrace::unlimited(); manifest.stages.len() - 1];
            let quant = LinkQuant { method, initial_bits: b, ..Default::default() };
            let spec = hlo_spec(&manifest, &dir, &cfg, traces, quant, None);
            let report = run(spec, Workload::one_pass(eval.clone(), manifest.microbatch))?;
            cells.push(format!("{:.2}%", report.accuracy * 100.0));
            eprintln!(
                "  [{} @ {}bit] acc={:.2}% ({} imgs, {:.1} img/s)",
                method.name(),
                b,
                report.accuracy * 100.0,
                report.images,
                report.throughput
            );
        }
        table.row(&cells);
    }
    table.print();
    println!("\npaper (ViT-Base/ImageNet): PTQ 2bit=0.44%  ACIQ 2bit=54.97%  PDA 2bit=70.82%");
    Ok(())
}
