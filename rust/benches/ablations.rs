//! Ablations over the design choices DESIGN.md calls out:
//!
//! 1. controller policy: Eq. 2 (power-of-two) vs bitwidth ladder;
//! 2. calibration cadence: per-microbatch vs amortized (calib_every);
//! 3. monitor window length: reaction latency vs stability;
//! 4. hysteresis margin: flapping vs responsiveness.
//!
//! All run on mock stages with a shaped link (the ablations isolate the
//! L3 control plane; model compute is irrelevant here and mocks keep the
//! suite fast).

use quantpipe::adapt::{AdaptConfig, Policy};
use quantpipe::benchkit::{section, Table};
use quantpipe::data::EvalSet;
use quantpipe::net::link::SimLink;
use quantpipe::net::mbps;
use quantpipe::net::trace::BandwidthTrace;
use quantpipe::net::transport::LinkSpec;
use quantpipe::pipeline::{mock_stage_factory, run, LinkQuant, PipelineSpec, Workload};
use quantpipe::quant::Method;
use std::sync::Arc;
use std::time::Duration;

fn eval_set(count: usize, dim: usize) -> Arc<EvalSet> {
    // one-hot rows: passthrough mock stages keep argmax = label.
    let mut images = Vec::new();
    let mut labels = Vec::new();
    for i in 0..count {
        let lab = i % dim;
        for c in 0..dim {
            images.push(if c == lab { 1.0 } else { 0.0 });
        }
        labels.push(lab as u32);
    }
    Arc::new(EvalSet { images, labels, count, dims: (1, 1, dim) })
}

const DIM: usize = 256;
const S: usize = 16;

fn spec(
    trace: BandwidthTrace,
    policy: Policy,
    window: u64,
    calib_every: u32,
    raise_margin: f64,
    target: f64,
) -> PipelineSpec {
    PipelineSpec {
        stages: (0..2)
            .map(|_| mock_stage_factory(1.0, 0.0, vec![S, DIM], Duration::from_micros(200)))
            .collect(),
        links: vec![LinkSpec::Sim(Arc::new(SimLink::new(trace)))],
        quant: LinkQuant { method: Method::Pda, calib_every, initial_bits: 32, ..Default::default() },
        adapt: Some(AdaptConfig { target_rate: target, microbatch: S, policy, raise_margin }),
        window,
        inflight: 2,
    }
}

fn main() -> quantpipe::Result<()> {
    let eval = eval_set(S * 16, DIM);
    // Frame @32-bit ≈ S*DIM*4 B = 16 KB; step the capacity so compression
    // requirements move through the ladder mid-run.
    let dynamic = BandwidthTrace::from_points(&[
        (0.0, mbps(40.0)),
        (2.0, mbps(4.0)),
        (4.0, mbps(12.0)),
    ]);
    let target = 2000.0; // img/s -> 8 ms budget/microbatch -> 16.4 Mb/s at fp32

    section("ablation 1: Eq.2 policy vs bitwidth ladder");
    let mut t = Table::new(&["policy", "throughput", "bits seq", "mean bytes/mb"]);
    for (name, policy) in [("eq2", Policy::Eq2), ("ladder", Policy::Ladder)] {
        let r = run(
            spec(dynamic.clone(), policy, 8, 1, 1.1, target),
            Workload::repeat(eval.clone(), S, 600),
        )?;
        t.row(&[
            name.into(),
            format!("{:.0} img/s", r.throughput),
            format!("{:?}", r.timeline.bits_sequence(0)),
            format!("{:.0}", r.link0_mean_bytes),
        ]);
    }
    t.print();
    println!("expected: ladder visits 6-bit and holds higher widths (better accuracy headroom);");
    println!("eq2 snaps to powers of two (coarser, sometimes over-compresses).");

    section("ablation 2: calibration cadence (calib_every)");
    let mut t = Table::new(&["calib_every", "throughput", "accuracy"]);
    for ce in [1u32, 10, 50] {
        let r = run(
            spec(BandwidthTrace::constant(mbps(6.0)), Policy::Ladder, 8, ce, 1.1, target),
            Workload::repeat(eval.clone(), S, 400),
        )?;
        t.row(&[
            format!("{ce}"),
            format!("{:.0} img/s", r.throughput),
            format!("{:.1}%", r.accuracy * 100.0),
        ]);
    }
    t.print();
    println!("expected: amortized calibration trades (tiny) accuracy for less control-path work;");
    println!("with stationary inputs the accuracy cost is ≈0 — the knob matters under drift.");

    section("ablation 3: window length (reaction vs stability)");
    let mut t = Table::new(&["window", "decisions", "bits seq", "throughput"]);
    for w in [4u64, 16, 64] {
        let r = run(
            spec(dynamic.clone(), Policy::Ladder, w, 1, 1.1, target),
            Workload::repeat(eval.clone(), S, 600),
        )?;
        t.row(&[
            format!("{w}"),
            format!("{}", r.timeline.points.len()),
            format!("{:?}", r.timeline.bits_sequence(0)),
            format!("{:.0} img/s", r.throughput),
        ]);
    }
    t.print();
    println!("expected: short windows react fast but wobble; long windows are stable but slow");
    println!("to recover after each capacity step (the paper's 'measurement latency').");

    section("ablation 4: hysteresis raise-margin");
    let mut t = Table::new(&["margin", "bits changes", "bits seq"]);
    for m in [1.0f64, 1.1, 1.5] {
        let r = run(
            spec(dynamic.clone(), Policy::Ladder, 8, 1, m, target),
            Workload::repeat(eval.clone(), S, 600),
        )?;
        let seq = r.timeline.bits_sequence(0);
        t.row(&[format!("{m}"), format!("{}", seq.len()), format!("{seq:?}")]);
    }
    t.print();
    println!("expected: larger margins suppress flapping at capacity boundaries at the cost");
    println!("of holding lower bitwidths (≈ lower accuracy) slightly longer.");
    Ok(())
}
