//! Fig 1 reproduction: pipeline throughput vs inter-stage bandwidth.
//!
//! The paper's motivating figure: as the (slowest) link's bandwidth drops,
//! overall pipeline throughput degrades — partitioning alone cannot fix a
//! communication bottleneck. We sweep the link capacity and compare
//! no-quantization, static 8-bit, and the adaptive controller; the
//! crossover where quantization starts to win (and where even 8-bit stops
//! helping) is the figure's story.

use quantpipe::adapt::AdaptConfig;
use quantpipe::benchkit::{hlo_spec, load_artifacts, section, Table};
use quantpipe::config::Config;
use quantpipe::net::mbps;
use quantpipe::net::trace::BandwidthTrace;
use quantpipe::pipeline::{run, LinkQuant, Workload};
use quantpipe::quant::Method;

fn main() -> quantpipe::Result<()> {
    let (manifest, dir, eval) = load_artifacts()?;
    let cfg = Config::default();
    let n_links = manifest.stages.len() - 1;
    let microbatches = 2 * eval.microbatches(manifest.microbatch) as u64;

    // Measure the compute ceiling first (unlimited links, no quant).
    let spec = hlo_spec(
        &manifest,
        &dir,
        &cfg,
        vec![BandwidthTrace::unlimited(); n_links],
        LinkQuant { method: Method::Pda, initial_bits: 32, ..Default::default() },
        None,
    );
    let ceiling = run(spec, Workload::repeat(eval.clone(), manifest.microbatch, microbatches))?;
    section("Fig 1: throughput vs bandwidth (all links shaped)");

    // Nominal rate from steady-state stage compute (the short ceiling run
    // underestimates it due to pipeline fill).
    let max_stage = ceiling.stage_compute_s.iter().cloned().fold(0.0f64, f64::max).max(1e-6);
    let nominal = manifest.microbatch as f64 / max_stage;
    let target = nominal * 0.75;
    // Sweep spans this testbed's Eq.2 thresholds: the 32-bit threshold is
    // full_bits/(S/R) ≈ 70 Mbps here, vs the paper's Jetson ratio (see
    // DESIGN.md §Substitutions on bandwidth scaling).
    let sweeps = [f64::INFINITY, 200.0, 70.0, 35.0, 18.0, 9.0, 4.5];

    println!("nominal {:.0} img/s, adaptive target R = {:.0} img/s", nominal, target);
    let mut table = Table::new(&["bandwidth", "no-quant", "8-bit", "adaptive", "adapt-bits", "adapt-acc"]);
    for bw_mbps in sweeps {
        let trace = || {
            if bw_mbps.is_infinite() {
                BandwidthTrace::unlimited()
            } else {
                BandwidthTrace::constant(mbps(bw_mbps))
            }
        };
        let mut cells = vec![if bw_mbps.is_infinite() {
            "inf".to_string()
        } else {
            format!("{bw_mbps:.0} Mbps")
        }];

        // no quantization
        let spec = hlo_spec(
            &manifest, &dir, &cfg,
            vec![trace(); n_links],
            LinkQuant { method: Method::Pda, initial_bits: 32, ..Default::default() },
            None,
        );
        let r = run(spec, Workload::repeat(eval.clone(), manifest.microbatch, microbatches))?;
        cells.push(format!("{:.1}", r.throughput));

        // static 8-bit
        let spec = hlo_spec(
            &manifest, &dir, &cfg,
            vec![trace(); n_links],
            LinkQuant { method: Method::Pda, initial_bits: 8, ..Default::default() },
            None,
        );
        let r8 = run(spec, Workload::repeat(eval.clone(), manifest.microbatch, microbatches))?;
        cells.push(format!("{:.1}", r8.throughput));

        // adaptive
        let adapt = AdaptConfig {
            target_rate: target,
            microbatch: manifest.microbatch,
            policy: quantpipe::adapt::Policy::Ladder,
            raise_margin: 1.1,
        };
        let mut acfg = cfg.clone();
        acfg.adapt.window = 8; // shorter window: the sweep runs are short
        let spec = hlo_spec(
            &manifest, &dir, &acfg,
            vec![trace(); n_links],
            LinkQuant { method: Method::Pda, initial_bits: 32, ..Default::default() },
            Some(adapt),
        );
        let ra = run(spec, Workload::repeat(eval.clone(), manifest.microbatch, microbatches))?;
        cells.push(format!("{:.1}", ra.throughput));
        cells.push(format!("{:?}", ra.timeline.final_bits(0).unwrap_or(32)));
        cells.push(format!("{:.1}%", ra.accuracy * 100.0));
        table.row(&cells);
        eprintln!("  [bw {bw_mbps}] done");
    }
    table.print();
    println!("\nshape check: no-quant throughput decays with bandwidth; adaptive holds near");
    println!("the target ({target:.1} img/s) until even 2-bit cannot fit the budget.");
    Ok(())
}
