//! Fig 5 over REAL sockets: replay the five-phase bandwidth trace
//! through the chaos shaper on a localhost TCP link and let the
//! controller react to *measured* write stalls — no `SimLink` anywhere.
//!
//! This is the trace-replay half of the chaos lab (`net::shaper`): the
//! same `BandwidthTrace` type that drives the simulated Fig 5 bench
//! (`fig5_adaptive`) here drives a token bucket on the sender's write
//! path, so the kernel socket, the framing layer and the controller see
//! the fade exactly as a congested uplink would present it.
//!
//! Artifact-free by design (mock stages + synthetic one-hot eval) so it
//! runs on any machine, including CI: the point is the transport and the
//! control loop, not the model. Emits `BENCH_fig5_tcp.json`; set
//! `QUANTPIPE_BENCH_GATE=<max_ratio>` to hard-fail when the cost fields
//! regress past the committed baseline by more than that ratio.

use quantpipe::adapt::{AdaptConfig, Policy};
use quantpipe::benchkit::{
    gate_vs_committed, print_delta_vs_committed, section, write_bench_json, Table,
};
use quantpipe::data::EvalSet;
use quantpipe::net::resilient::ResilienceConfig;
use quantpipe::net::shaper::{LinkShaper, ShaperSpec};
use quantpipe::net::trace::BandwidthTrace;
use quantpipe::net::transport::LinkSpec;
use quantpipe::pipeline::{mock_stage_factory, run, LinkQuant, PipelineSpec, Workload};
use quantpipe::quant::Method;
use quantpipe::util::json::Value;
use std::sync::Arc;
use std::time::Duration;

fn main() -> quantpipe::Result<()> {
    // Mock-stage geometry: 8x256 f32 ≈ 8 KB per raw activation frame,
    // 5 ms of "compute" on the middle stage. The compute ceiling is then
    // exact (no probe run needed): nominal = s / compute.
    let s = 8usize;
    let classes = 256usize;
    let compute = Duration::from_millis(5);
    let nominal = s as f64 / compute.as_secs_f64();
    let target = nominal * 0.75;
    let budget_secs = s as f64 / target;

    // Eq. 2 thresholds scaled to THIS testbed, exactly as fig5_adaptive
    // derives them: capacity needed to hold bitwidth q at the target rate.
    let full_bits = (s * classes) as f64 * 32.0;
    let b_min = |q: f64| full_bits * (q / 32.0) / budget_secs;
    let p1 = b_min(32.0) * 0.85; // forces 16-bit
    let p2 = b_min(2.0) * 1.15; // forces 2-bit
    let p3 = b_min(8.0) * 1.2; // recovers to 8-bit

    let window = 4u64;
    let phase_mb = 40u64;
    let total = 5 * phase_mb;
    let phase_secs = budget_secs * phase_mb as f64 * 1.3;
    let trace = BandwidthTrace::from_points(&[
        (0.0, f64::INFINITY),
        (phase_secs, p1),
        (2.0 * phase_secs, p2),
        (3.0 * phase_secs, p3),
        (4.0 * phase_secs, f64::INFINITY),
    ]);

    section("Fig 5 over TCP: trace replay through the chaos shaper");
    println!(
        "nominal {nominal:.0} img/s, target R = {target:.0} img/s, phase ≈ {phase_secs:.2}s"
    );
    println!(
        "phase capacities: inf / {:.1} / {:.2} / {:.2} Mbps / inf",
        p1 / 1e6,
        p2 / 1e6,
        p3 / 1e6
    );

    // One resilient TCP conduit whose write path carries the trace: the
    // shaper sleeps the sender until the token bucket admits each frame,
    // so the controller's window monitor measures the fade as real
    // backpressure on a real socket.
    let shaper = Arc::new(LinkShaper::new(ShaperSpec { trace, seed: 7, ..ShaperSpec::default() }));
    let mut link0 = LinkSpec::tcp_loopback_striped(1, ResilienceConfig::default())?;
    anyhow::ensure!(
        link0.set_stripe_shapers(vec![Some(shaper.clone())]),
        "striped link refused the shaper"
    );
    let link1 = LinkSpec::tcp_loopback()?;

    let spec = PipelineSpec {
        stages: vec![
            mock_stage_factory(1.0, 0.0, vec![s, classes], Duration::ZERO),
            mock_stage_factory(1.0, 0.0, vec![s, classes], compute),
            mock_stage_factory(1.0, 0.0, vec![s, classes], Duration::ZERO),
        ],
        links: vec![link0, link1],
        quant: LinkQuant { method: Method::Aciq, initial_bits: 32, ..Default::default() },
        adapt: Some(AdaptConfig {
            target_rate: target,
            microbatch: s,
            policy: Policy::Ladder,
            raise_margin: 1.1,
        }),
        window,
        inflight: 2,
    };
    let eval = Arc::new(EvalSet::synthetic_onehot(64, classes));
    let report = run(spec, Workload::repeat(eval, s, total))?;
    anyhow::ensure!(
        report.errors.is_empty() && report.microbatches == total,
        "trace replay run was not clean: {:?}",
        report.errors
    );

    let mut table = Table::new(&["t(s)", "bw meas (Mbps)", "rate (img/s)", "bits", "util"]);
    for p in report.timeline.points.iter().filter(|p| p.stage == 0) {
        table.row(&[
            format!("{:.1}", p.t),
            if p.bandwidth_bps.is_infinite() {
                "inf".into()
            } else {
                format!("{:.1}", p.bandwidth_bps / 1e6)
            },
            format!("{:.0}", p.rate),
            format!("{}", p.bits),
            format!("{:.2}", p.util),
        ]);
    }
    table.print();
    println!("bitwidth sequence (link 0): {:?}", report.timeline.bits_sequence(0));
    let sh = shaper.stats();
    println!(
        "shaper: {} frames shaped, {:.2}s total serialization stall",
        sh.frames,
        sh.stalled_us as f64 / 1e6
    );
    println!(
        "overall throughput {:.1} img/s, accuracy {:.2}%",
        report.throughput,
        report.accuracy * 100.0
    );

    let bits_seq = Value::Arr(
        report.timeline.bits_sequence(0).iter().map(|&b| Value::Num(b as f64)).collect(),
    );
    let fields = [
        ("throughput_img_s", report.throughput),
        ("accuracy", report.accuracy),
        ("wall_secs", report.wall_secs),
        ("microbatches", report.microbatches as f64),
        ("images", report.images as f64),
        ("target_rate_img_s", target),
        ("nominal_img_s", nominal),
        ("p50_latency_s", report.latency.quantile(0.5).as_secs_f64()),
        ("p99_latency_s", report.latency.quantile(0.99).as_secs_f64()),
        ("shaper_stall_secs", sh.stalled_us as f64 / 1e6),
        ("final_bits_link0", report.timeline.final_bits(0).unwrap_or(32) as f64),
        ("bits_steps_link0", report.timeline.bits_sequence(0).len() as f64),
        ("window_points", report.timeline.points.len() as f64),
    ];
    let bench_path = write_bench_json("fig5_tcp", &fields, &[("bits_sequence_link0", bits_seq)])?;
    println!("bench json -> {}", bench_path.display());

    // Drift line always; hard gate only when asked (CI sets the ratio).
    // Only lower-is-better fields participate — the gate treats every
    // field as a cost.
    let costs = [
        ("wall_secs", report.wall_secs),
        ("p50_latency_s", report.latency.quantile(0.5).as_secs_f64()),
        ("p99_latency_s", report.latency.quantile(0.99).as_secs_f64()),
    ];
    print_delta_vs_committed("fig5_tcp", &costs);
    if let Ok(raw) = std::env::var("QUANTPIPE_BENCH_GATE") {
        let max_ratio: f64 = raw
            .parse()
            .map_err(|e| anyhow::anyhow!("QUANTPIPE_BENCH_GATE wants a ratio like 1.5: {e}"))?;
        gate_vs_committed("fig5_tcp", &costs, max_ratio)?;
        println!("bench gate: within {max_ratio:.2}x of the committed baseline");
    }
    Ok(())
}
