//! Fig 4 reproduction: estimated distribution with and without directed
//! search at partition boundaries (paper: blocks 4 and 6).
//!
//! The figure's claim: the Laplace fit from the moment estimate `b_E`
//! misses the real histogram; the directed search finds `b*` whose density
//! matches far better — "DS-ACIQ decreases the MSE by around 50%". We
//! report the Eq. 1 density-fit MSE at `b_E` vs `b*` for every boundary
//! (real calibration activations) plus controlled mixtures that exhibit
//! the estimated-vs-real gap strongly.

use quantpipe::benchkit::{load_artifacts, section, Table};
use quantpipe::data::load_calib;
use quantpipe::quant::ds_aciq::{ds_aciq_b, DEFAULT_STEPS};
use quantpipe::quant::{aciq, calibrate, uniform, Method};
use quantpipe::util::rng::Rng;

fn report_row(table: &mut Table, name: &str, x: &[f32]) {
    let r = ds_aciq_b(x, 2, DEFAULT_STEPS);
    let m_aciq = uniform::quant_mse(x, &calibrate(x, Method::Aciq, 2));
    let m_ds = uniform::quant_mse(x, &calibrate(x, Method::DsAciq, 2));
    table.row(&[
        name.to_string(),
        format!("{:.4}", r.b_e),
        format!("{:.4}", r.b_r),
        format!("{:.4}", r.b_star),
        format!("{:.3e}", r.fit_mse_e),
        format!("{:.3e}", r.fit_mse_star),
        format!("{:.1}%", r.improvement() * 100.0),
        format!("{:.4}", m_aciq),
        format!("{:.4}", m_ds),
    ]);
}

fn main() -> quantpipe::Result<()> {
    let (manifest, dir, _eval) = load_artifacts()?;
    let tensors = load_calib(dir.join(&manifest.calib.file))?;

    section("Fig 4: Eq.1 density-fit MSE, ACIQ estimate (b_E) vs directed search (b*)");
    let mut table = Table::new(&[
        "tensor", "b_E", "b_R", "b*", "fit(b_E)", "fit(b*)", "fit-impr", "qMSE aciq", "qMSE ds",
    ]);
    for (i, t) in tensors.iter().enumerate() {
        report_row(
            &mut table,
            &format!("boundary {} (block {})", i, manifest.stages[i].blocks[1]),
            &t.data,
        );
    }

    // Controlled estimated-vs-real-gap distributions (the Fig 4 mechanism
    // in isolation): sharp bulk + wide tail ⇒ moment estimate overshoots.
    let mut rng = Rng::seed(17);
    let mut mix = rng.laplace_vec(80000, 0.1);
    mix.extend(rng.laplace_vec(8000, 2.0));
    report_row(&mut table, "peaked mixture (synthetic)", &mix);

    let mut gaussmix = rng.gaussian_vec(60000, 0.2);
    gaussmix.extend(rng.gaussian_vec(6000, 3.0));
    report_row(&mut table, "gauss scale-mixture", &gaussmix);

    let pure = rng.laplace_vec(60000, 1.0);
    report_row(&mut table, "pure laplace (control)", &pure);

    table.print();
    println!("\nshape check: on gap distributions the fit improves ~50% or more and");
    println!("b* < b_E (tighter clip); on the pure-Laplace control DS barely moves b.");
    println!("Also sanity: aciq::ratio(2) = {:.3} (paper/Banner: 2.83).", aciq::ratio(2));
    Ok(())
}
