//! Table 1 as a runnable example: accuracy of {naive, ACIQ, DS-ACIQ, PDA}
//! × bitwidths over the eval set, quantizing every boundary activation.
//!
//! ```bash
//! make artifacts && cargo run --release --example accuracy_sweep [-- 8,4,2]
//! ```

use quantpipe::benchkit::{hlo_spec, load_artifacts, Table};
use quantpipe::config::Config;
use quantpipe::net::trace::BandwidthTrace;
use quantpipe::pipeline::{run, LinkQuant, Workload};
use quantpipe::quant::Method;

fn main() -> quantpipe::Result<()> {
    let bits: Vec<u8> = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "32,16,8,6,4,2".into())
        .split(',')
        .map(|b| b.trim().parse().expect("bitwidth"))
        .collect();

    let (manifest, dir, eval) = load_artifacts()?;
    let cfg = Config::default();
    println!(
        "Table 1 sweep — fp32 reference {:.2}%, eval {} images",
        manifest.model.fp32_top1 * 100.0,
        eval.count
    );

    let mut headers: Vec<String> = vec!["method".into()];
    headers.extend(bits.iter().map(|b| format!("{b}bit")));
    let hrefs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&hrefs);

    for method in [Method::Naive, Method::Aciq, Method::DsAciq, Method::Pda] {
        let mut cells = vec![method.name().to_string()];
        for &b in &bits {
            let spec = hlo_spec(
                &manifest, &dir, &cfg,
                vec![BandwidthTrace::unlimited(); manifest.stages.len() - 1],
                LinkQuant { method, initial_bits: b, ..Default::default() },
                None,
            );
            let report = run(spec, Workload::one_pass(eval.clone(), manifest.microbatch))?;
            cells.push(format!("{:.2}%", report.accuracy * 100.0));
        }
        table.row(&cells);
    }
    table.print();
    Ok(())
}
