//! The adaptive pipeline over REAL localhost TCP sockets — no `SimLink`
//! anywhere on the data path. Three mock stages; the middle one is
//! artificially slow, so it stops draining its socket while "computing",
//! the kernel buffers fill, and stage 0's writes stall. The controller
//! never sees a configured bandwidth: it infers congestion purely from
//! measured write-stall time and sheds bits, exactly as it would across
//! machines.
//!
//! ```bash
//! cargo run --release --example tcp_pipeline
//! ```
//!
//! No AOT artifacts needed (mock stages + synthetic one-hot eval).
//!
//! For a true multi-process deployment of the same code path, run one
//! process per endpoint (any start order; connects retry):
//!
//! ```bash
//! quantpipe coordinate --config configs/tcp_demo.json --synthetic 256x16 --microbatches 64 &
//! quantpipe worker --stage 0 --config configs/tcp_demo.json --mock 64x16 --stages 3 &
//! quantpipe worker --stage 1 --config configs/tcp_demo.json --mock 64x16 --stages 3 &
//! quantpipe worker --stage 2 --config configs/tcp_demo.json --mock 64x16 --stages 3 &
//! ```

use quantpipe::adapt::{AdaptConfig, Policy};
use quantpipe::data::EvalSet;
use quantpipe::net::transport::LinkSpec;
use quantpipe::pipeline::{mock_stage_factory, run, LinkQuant, PipelineSpec, Workload};
use quantpipe::quant::Method;
use std::sync::Arc;
use std::time::Duration;

fn main() -> quantpipe::Result<()> {
    let s = 32usize;
    let wide = 4096usize; // 512 KB raw frame: bigger than loopback buffers
    let stall = Duration::from_millis(30);

    let spec = PipelineSpec {
        stages: vec![
            mock_stage_factory(1.0, 0.0, vec![s, wide], Duration::ZERO),
            mock_stage_factory(1.0, 0.0, vec![s, wide], stall), // the bottleneck
            mock_stage_factory(1.0, 0.0, vec![s, 4], Duration::ZERO),
        ],
        links: vec![LinkSpec::tcp_loopback()?, LinkSpec::tcp_loopback()?],
        quant: LinkQuant { method: Method::Pda, initial_bits: 32, ..Default::default() },
        adapt: Some(AdaptConfig {
            target_rate: 6400.0, // 5 ms budget per microbatch
            microbatch: s,
            policy: Policy::Ladder,
            raise_margin: 1.1,
        }),
        window: 4,
        inflight: 2,
    };

    let eval = Arc::new(EvalSet::synthetic_onehot(64, 4));
    let report = run(spec, Workload::repeat(eval, s, 60))?;

    println!("per-window decisions on the stage-0 socket (all bandwidth MEASURED):");
    println!("{:>7} {:>12} {:>10} {:>5} {:>6}", "t(s)", "bw(Mbps)", "rate", "bits", "util");
    for p in report.timeline.points.iter().filter(|p| p.stage == 0) {
        let bw = if p.bandwidth_bps.is_infinite() {
            "inf".into()
        } else {
            format!("{:.0}", p.bandwidth_bps / 1e6)
        };
        println!("{:>7.1} {:>12} {:>10.0} {:>5} {:>6.2}", p.t, bw, p.rate, p.bits, p.util);
    }
    println!("\nbitwidth sequence: {:?}", report.timeline.bits_sequence(0));
    println!(
        "throughput {:.0} img/s | link0 mean {:.0} B/frame | wall {:.1}s",
        report.throughput, report.link0_mean_bytes, report.wall_secs
    );
    if !report.errors.is_empty() {
        eprintln!("link failures: {:?}", report.errors);
    }
    Ok(())
}
