//! The Fig 5 scenario as a runnable example: five bandwidth phases on the
//! first inter-stage link (unlimited → 400 → 50 → 200 Mbps → unlimited),
//! QuantPipe adapting its bitwidth from runtime measurements only.
//!
//! ```bash
//! make artifacts && cargo run --release --example adaptive_bandwidth
//! ```
//!
//! Writes `adaptive_timeline.csv` with the per-window tracks.

use quantpipe::adapt::AdaptConfig;
use quantpipe::benchkit::{hlo_spec, load_artifacts};
use quantpipe::config::Config;
use quantpipe::net::trace::BandwidthTrace;
use quantpipe::pipeline::{run, LinkQuant, Workload};
use quantpipe::quant::Method;

fn main() -> quantpipe::Result<()> {
    let (manifest, dir, eval) = load_artifacts()?;
    let mut cfg = Config::default();
    cfg.adapt.window = 10;
    let n_links = manifest.stages.len() - 1;
    let phase_mb = 50u64;

    // Measure the nominal (unconstrained) throughput to set R and phase times.
    let ceiling = run(
        hlo_spec(
            &manifest, &dir, &cfg,
            vec![BandwidthTrace::unlimited(); n_links],
            LinkQuant { method: Method::Pda, initial_bits: 32, ..Default::default() },
            None,
        ),
        Workload::repeat(eval.clone(), manifest.microbatch, phase_mb),
    )?;
    let max_stage = ceiling.stage_compute_s.iter().cloned().fold(0.0f64, f64::max).max(1e-6);
    let nominal = manifest.microbatch as f64 / max_stage;
    let target = nominal * 0.75;
    let budget = manifest.microbatch as f64 / target;
    let phase_secs = budget * phase_mb as f64 * 1.3;
    println!(
        "nominal {:.0} img/s → target R = {:.0} img/s, phase ≈ {:.1}s",
        nominal, target, phase_secs
    );

    // Phase capacities from Eq.2's thresholds on THIS testbed (the paper's
    // absolute Mbps encode the Jetson compute:comm ratio; see DESIGN.md).
    let full_bits = manifest.activation_shape.iter().product::<usize>() as f64 * 32.0;
    let b_min = |q: f64| full_bits * (q / 32.0) / budget;
    let mut traces = vec![BandwidthTrace::unlimited(); n_links];
    traces[0] = BandwidthTrace::from_points(&[
        (0.0, f64::INFINITY),
        (phase_secs, b_min(32.0) * 0.85),
        (2.0 * phase_secs, b_min(2.0) * 1.15),
        (3.0 * phase_secs, b_min(8.0) * 1.2),
        (4.0 * phase_secs, f64::INFINITY),
    ]);

    let spec = hlo_spec(
        &manifest, &dir, &cfg,
        traces,
        LinkQuant { method: Method::Pda, initial_bits: 32, ..Default::default() },
        Some(AdaptConfig {
            target_rate: target,
            microbatch: manifest.microbatch,
            policy: quantpipe::adapt::Policy::Ladder,
            raise_margin: 1.1,
        }),
    );
    let report = run(spec, Workload::repeat(eval, manifest.microbatch, 5 * phase_mb))?;

    println!("\nper-window decisions on the shaped link:");
    println!("{:>7} {:>12} {:>10} {:>5} {:>6}", "t(s)", "bw(Mbps)", "rate", "bits", "util");
    for p in report.timeline.points.iter().filter(|p| p.stage == 0) {
        let bw = if p.bandwidth_bps.is_infinite() { "inf".into() } else { format!("{:.0}", p.bandwidth_bps / 1e6) };
        println!("{:>7.1} {:>12} {:>10.0} {:>5} {:>6.2}", p.t, bw, p.rate, p.bits, p.util);
    }
    println!("\nbitwidth sequence: {:?}", report.timeline.bits_sequence(0));
    println!("throughput {:.1} img/s | accuracy {:.2}%", report.throughput, report.accuracy * 100.0);
    std::fs::write("adaptive_timeline.csv", report.timeline.to_csv())?;
    println!("timeline -> adaptive_timeline.csv");
    Ok(())
}
