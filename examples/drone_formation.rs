//! The paper's motivating deployment: a drone formation running real-time
//! detection across its members' processors (§1). Four stages over three
//! radio links with *independent* fluctuating bandwidths, packet loss and
//! jitter — each link gets its own adaptive PDA controller.
//!
//! ```bash
//! make artifacts && cargo run --release --example drone_formation
//! ```

use quantpipe::adapt::AdaptConfig;
use quantpipe::benchkit::{hlo_spec, load_artifacts};
use quantpipe::config::Config;
use quantpipe::net::trace::BandwidthTrace;
use quantpipe::pipeline::{run, LinkQuant, Workload};
use quantpipe::quant::Method;

fn main() -> quantpipe::Result<()> {
    let (manifest, dir, eval) = load_artifacts()?;
    let mut cfg = Config::default();
    cfg.adapt.window = 10;
    cfg.net.loss_p = 0.02; // radio links drop frames
    cfg.net.jitter_ms = 0.5;
    cfg.net.latency_us = 800;
    let n_links = manifest.stages.len() - 1;
    anyhow::ensure!(n_links >= 3, "expected ≥4 stages in artifacts");

    // Nominal ceiling for target-rate calibration.
    let ceiling = run(
        hlo_spec(
            &manifest, &dir, &cfg,
            vec![BandwidthTrace::unlimited(); n_links],
            LinkQuant { method: Method::Pda, initial_bits: 32, ..Default::default() },
            None,
        ),
        Workload::repeat(eval.clone(), manifest.microbatch, 40),
    )?;
    // Nominal from steady-state stage compute; capacities scaled to this
    // testbed's Eq.2 thresholds (see DESIGN.md on bandwidth scaling).
    let max_stage = ceiling.stage_compute_s.iter().cloned().fold(0.0f64, f64::max).max(1e-6);
    let nominal = manifest.microbatch as f64 / max_stage;
    let target = nominal * 0.7;
    let full_bits = manifest.activation_shape.iter().product::<usize>() as f64 * 32.0;
    let b_min = |q: f64| full_bits * (q / 32.0) / (manifest.microbatch as f64 / target);
    let t = ceiling.wall_secs; // one 40-microbatch span

    // Independent per-link radio schedules: drone 1↔2 degrades early,
    // 2↔3 mid-run, 3↔4 has a brief outage-grade dip.
    let traces = vec![
        BandwidthTrace::from_points(&[(0.0, f64::INFINITY), (t, b_min(16.0) * 1.2), (3.0 * t, f64::INFINITY)]),
        BandwidthTrace::from_points(&[(0.0, f64::INFINITY), (2.0 * t, b_min(8.0) * 1.2), (4.0 * t, f64::INFINITY)]),
        BandwidthTrace::from_points(&[(0.0, b_min(32.0) * 2.0), (2.5 * t, b_min(2.0) * 1.3), (3.5 * t, b_min(32.0) * 1.5)]),
    ];

    println!(
        "drone formation: {} stages, nominal {:.0} img/s, target {:.0} img/s, loss 2%",
        manifest.stages.len(),
        nominal,
        target
    );

    let spec = hlo_spec(
        &manifest, &dir, &cfg,
        traces,
        LinkQuant { method: Method::Pda, initial_bits: 32, ..Default::default() },
        Some(AdaptConfig {
            target_rate: target,
            microbatch: manifest.microbatch,
            policy: quantpipe::adapt::Policy::Ladder,
            raise_margin: 1.1,
        }),
    );
    let report = run(spec, Workload::repeat(eval, manifest.microbatch, 240))?;

    println!("\nthroughput {:.1} img/s | accuracy {:.2}%", report.throughput, report.accuracy * 100.0);
    for link in 0..n_links {
        println!(
            "link {link}: bitwidth sequence {:?}",
            report.timeline.bits_sequence(link)
        );
    }
    println!(
        "p50/p99 latency {:?} / {:?}",
        report.latency.quantile(0.5),
        report.latency.quantile(0.99)
    );
    println!("\neach link adapted independently — the formation held {:.0}% of nominal",
        report.throughput / nominal * 100.0);
    Ok(())
}
