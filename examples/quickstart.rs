//! Quickstart: load the AOT artifacts, run the 4-stage pipeline over the
//! eval set with adaptive PDA on unconstrained links, print the report.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use quantpipe::benchkit::load_artifacts;
use quantpipe::config::Config;
use quantpipe::net::trace::BandwidthTrace;
use quantpipe::pipeline::{run, LinkQuant, Workload};
use quantpipe::quant::Method;

fn main() -> quantpipe::Result<()> {
    let (manifest, dir, eval) = load_artifacts()?;
    println!(
        "loaded ViT ({:.2}M params, fp32 top-1 {:.2}%), {} stages, microbatch {}",
        manifest.model.params as f64 / 1e6,
        manifest.model.fp32_top1 * 100.0,
        manifest.stages.len(),
        manifest.microbatch
    );

    let cfg = Config::default();
    let spec = quantpipe::benchkit::hlo_spec(
        &manifest,
        &dir,
        &cfg,
        vec![BandwidthTrace::unlimited(); manifest.stages.len() - 1],
        LinkQuant { method: Method::Pda, initial_bits: 32, ..Default::default() },
        Some(cfg.adapt_config()?),
    );

    let report = run(spec, Workload::one_pass(eval, manifest.microbatch))?;
    println!("processed {} images in {:.2}s", report.images, report.wall_secs);
    println!("throughput      {:.1} img/s", report.throughput);
    println!("top-1 accuracy  {:.2}%", report.accuracy * 100.0);
    println!(
        "p50 / p99 microbatch latency: {:?} / {:?}",
        report.latency.quantile(0.5),
        report.latency.quantile(0.99)
    );
    println!("per-stage compute (s): {:?}", report.stage_compute_s);
    Ok(())
}
